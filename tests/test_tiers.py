"""Storage tiers: append-log write path, compacted read path, durability.

The contract under test (paper §4.1's read/write I/O split as
LSM-for-cuboids):

* `LogBackend` turns write batches into sequential appends and rebuilds
  its index by replaying segments on open — torn tails (a crash
  mid-append) are truncated, never served, and replay is idempotent.
* `DirectoryBackend.put` with fsync on can never publish a torn cuboid
  and never loses an acked write (crash-point injection at each syscall
  boundary); orphaned ``.tmp`` files are swept on open and counted.
* `MemoryBackend` survives concurrent ``keys()`` vs ``put_many``
  (the rebalance-scan race).
* A tiered store (log write tier over a compacted read tier) stays
  bit-identical to a plain single-backend oracle through writes, deletes,
  flushes, compactions, reopens, crashes at every injected point, and —
  at cluster scope — across 1/2/4 shards during live compaction,
  rebalance, and failover-then-heal re-replication.
"""
import os
import threading
import time

import numpy as np
import pytest

from repro.cluster import ClusterStore, VolumeService
from repro.cluster.api import url_dispatch
from repro.cluster.cache import enable_write_behind
from repro.core.compact import Compactor, compact_store
from repro.core.cutout import cutout, write_cutout
from repro.core.store import (
    CuboidStore,
    DirectoryBackend,
    MemoryBackend,
    set_crash_hook,
)
from repro.core.wal import HEADER_BYTES, LogBackend, TierPolicy, tiered_store
from repro.ft import ClusterWatch, StorageSupervisor

from test_rebalance import (
    CUBOID,
    N_CELLS,
    SHAPE,
    rand_box,
    random_ops,
    run_interleaving,
    spec,
    volume,
)


class SimulatedCrash(BaseException):
    """Raised by the crash hook; BaseException so nothing swallows it."""


@pytest.fixture
def crash_at():
    """Install a hook that raises at one named crash point."""
    def arm(point, after=0):
        state = {"n": 0}

        def hook(name):
            if name == point:
                state["n"] += 1
                if state["n"] > after:
                    raise SimulatedCrash(point)

        set_crash_hook(hook)
        return state

    yield arm
    set_crash_hook(None)


def log_policy(**kw):
    kw.setdefault("write_tier", "log")
    kw.setdefault("fsync", False)  # the fsync *ordering* tests force it on
    return TierPolicy(**kw)


# ------------------------------------------------------- LogBackend unit --


def test_log_backend_roundtrip_and_tombstones(tmp_path):
    log = LogBackend(str(tmp_path), fsync=False)
    log.put((0, 0, 1), b"aa")
    log.put_many([((0, 0, 2), b"bb"), ((1, 0, 3), b"cc")])
    assert log.get((0, 0, 1)) == b"aa"
    assert log.get_many([(0, 0, 2), (1, 0, 3), (9, 9, 9)]) == [b"bb", b"cc", None]
    assert (0, 0, 2) in log and (9, 9, 9) not in log
    log.delete((0, 0, 2))
    # tombstone: gone from keys(), but probe reports a *definitive* absence
    assert sorted(log.keys()) == [(0, 0, 1), (1, 0, 3)]
    assert log.tombstone_keys() == {(0, 0, 2)}
    assert log.probe((0, 0, 2)) == (True, None)
    assert log.probe((5, 5, 5)) == (False, None)
    assert log.probe_many([(0, 0, 1), (0, 0, 2), (5, 5, 5)]) == [
        (True, b"aa"), (True, None), (False, None)]
    s = log.stats()
    assert s["live_keys"] == 2 and s["tombstones"] == 1
    assert s["appends"] == 4 and s["torn_truncated"] == 0


def test_log_backend_rotation_and_seal(tmp_path):
    log = LogBackend(str(tmp_path), segment_bytes=128, fsync=False)
    for m in range(6):
        log.put((0, 0, m), bytes(64))  # every record > half a segment
    assert log.stats()["segments"] >= 3
    sealed = log.sealed_segments()
    assert sealed == sorted(sealed) and len(sealed) >= 2
    log.seal_active()
    # everything written is now compactable; a fresh active segment exists
    assert log.stats()["active_bytes"] == 0
    for m in range(6):
        assert log.get((0, 0, m)) == bytes(64)


def test_log_backend_reopen_rebuilds_index(tmp_path):
    log = LogBackend(str(tmp_path), segment_bytes=256, fsync=False)
    rng = np.random.default_rng(0)
    want = {}
    for i in range(40):
        key = (0, 0, int(rng.integers(0, 10)))
        if rng.random() < 0.25:
            log.delete(key)
            want[key] = None
        else:
            blob = bytes(rng.integers(0, 256, size=rng.integers(1, 50),
                                      dtype=np.uint8))
            log.put(key, blob)
            want[key] = blob
    log.close()
    # replay is idempotent: reopening twice converges to the same view
    for _ in range(2):
        reopened = LogBackend(str(tmp_path), segment_bytes=256, fsync=False)
        for key, blob in want.items():
            assert reopened.get(key) == blob
            assert reopened.probe(key) == (True, blob)  # tombstones survive
        assert reopened.torn_truncated == 0
        reopened.close()


@pytest.mark.parametrize("cut", ["header", "payload", "crc"])
def test_log_backend_truncates_torn_tail(tmp_path, cut):
    log = LogBackend(str(tmp_path), fsync=False)
    log.put((0, 0, 1), b"x" * 20)
    log.put((0, 0, 2), b"y" * 20)
    path = log._segment_path(log._active)
    size = os.path.getsize(path)
    log.close()
    chop = {"header": 20 + HEADER_BYTES - 4, "payload": 8, "crc": 20}[cut]
    with open(path, "r+b") as f:
        f.truncate(size - chop)
    reopened = LogBackend(str(tmp_path), fsync=False)
    # the whole torn record is gone; the earlier record is intact
    assert reopened.torn_truncated == 1
    assert reopened.get((0, 0, 1)) == b"x" * 20
    assert reopened.probe((0, 0, 2)) == (False, None)
    # and the tail is clean: appends resume without another truncation
    reopened.put((0, 0, 3), b"z")
    reopened.close()
    again = LogBackend(str(tmp_path), fsync=False)
    assert again.torn_truncated == 0
    assert again.get((0, 0, 3)) == b"z"


def test_log_backend_rejects_corrupt_crc(tmp_path):
    log = LogBackend(str(tmp_path), fsync=False)
    log.put((0, 0, 1), b"a" * 30)
    path = log._segment_path(log._active)
    log.close()
    with open(path, "r+b") as f:
        f.seek(os.path.getsize(path) - 1)
        f.write(b"\xff")  # flip the last payload byte; header stays valid
    reopened = LogBackend(str(tmp_path), fsync=False)
    assert reopened.torn_truncated == 1
    assert reopened.probe((0, 0, 1)) == (False, None)


def test_log_backend_crash_before_sync_is_not_indexed(tmp_path, crash_at):
    log = LogBackend(str(tmp_path), fsync=False)
    log.put((0, 0, 1), b"ok")
    crash_at("wal.append.written")
    with pytest.raises(SimulatedCrash):
        log.put((0, 0, 2), b"lost")
    # the crashed append never reached the index: not acked, not served
    assert log.probe((0, 0, 2)) == (False, None)
    assert log.get((0, 0, 1)) == b"ok"
    set_crash_hook(None)
    # recovery MAY surface the record (its bytes were complete on disk);
    # what it must never do is serve a torn one or lose the acked write
    reopened = LogBackend(str(tmp_path), fsync=False)
    assert reopened.get((0, 0, 1)) == b"ok"
    got = reopened.probe((0, 0, 2))
    assert got in ((False, None), (True, b"lost"))


# ------------------------------------------- DirectoryBackend durability --


def test_directory_backend_fsync_ordering(tmp_path, monkeypatch):
    """Data must be durable BEFORE the rename publishes it, and the
    directory entry after — the exact ordering whose absence was the bug."""
    calls = []
    real_fsync, real_replace = os.fsync, os.replace
    monkeypatch.setattr(os, "fsync", lambda fd: (calls.append("fsync"),
                                                 real_fsync(fd))[1])
    monkeypatch.setattr(
        os, "replace",
        lambda a, b: (calls.append("replace"), real_replace(a, b))[1])
    be = DirectoryBackend(str(tmp_path), fsync=True)
    be.put((0, 0, 0), b"warm")  # pay the one-time mkdir-chain syncs
    calls.clear()
    be.put((0, 0, 1), b"blob")
    assert calls == ["fsync", "replace", "fsync"]
    # and with fsync off the put must not pay any sync at all
    calls.clear()
    DirectoryBackend(str(tmp_path / "nosync"), fsync=False).put(
        (0, 0, 1), b"blob")
    assert "fsync" not in calls


@pytest.mark.parametrize("point", ["dir.put.written", "dir.put.synced"])
def test_directory_backend_crash_before_rename(tmp_path, crash_at, point):
    """A crash before the rename leaves the OLD value published and a tmp
    orphan — never a torn file under the real name."""
    be = DirectoryBackend(str(tmp_path), fsync=True)
    be.put((0, 0, 1), b"old")
    crash_at(point)
    with pytest.raises(SimulatedCrash):
        be.put((0, 0, 1), b"new")
    set_crash_hook(None)
    assert be.get((0, 0, 1)) == b"old"
    # "restart": reopen over the same root — the orphan is swept + counted
    reopened = DirectoryBackend(str(tmp_path), fsync=True)
    assert reopened.swept_tmp == 1
    assert reopened.get((0, 0, 1)) == b"old"
    assert not [f for f in os.listdir(tmp_path / "0" / "0")
                if f.endswith(".tmp")]


def test_directory_backend_crash_after_rename_keeps_new_value(
        tmp_path, crash_at):
    be = DirectoryBackend(str(tmp_path), fsync=True)
    be.put((0, 0, 1), b"old")
    crash_at("dir.put.renamed")
    with pytest.raises(SimulatedCrash):
        be.put((0, 0, 1), b"new")
    set_crash_hook(None)
    # the rename happened and the data beneath it was already synced: the
    # new value is whole (a pre-fix crash here could surface torn bytes)
    reopened = DirectoryBackend(str(tmp_path), fsync=True)
    assert reopened.get((0, 0, 1)) == b"new"
    assert reopened.swept_tmp == 0


def test_tmp_sweep_counts_into_path_stats(tmp_path):
    root = tmp_path / "data"
    be = DirectoryBackend(str(root))
    be.put((0, 0, 1), b"keep")
    # orphans at several depths, as interrupted puts would leave them
    (root / "0" / "0" / "00000000000000ff.bin.tmp").write_bytes(b"torn")
    (root / "0" / "junk.tmp").write_bytes(b"torn")
    store = CuboidStore(spec(), backend=DirectoryBackend(str(root)))
    assert store.read_stats.tmp_swept == 2
    assert store.read_backend.get((0, 0, 1)) == b"keep"
    assert list(store.read_backend.keys()) == [(0, 0, 1)]


def test_fsync_env_default(tmp_path, monkeypatch):
    monkeypatch.delenv("REPRO_FSYNC", raising=False)
    assert DirectoryBackend(str(tmp_path / "a")).fsync is False
    assert LogBackend(str(tmp_path / "b")).fsync is True  # the ack boundary
    monkeypatch.setenv("REPRO_FSYNC", "1")
    assert DirectoryBackend(str(tmp_path / "c")).fsync is True
    monkeypatch.setenv("REPRO_FSYNC", "0")
    assert LogBackend(str(tmp_path / "d")).fsync is False


# ------------------------------------------- MemoryBackend concurrency --


def test_memory_backend_keys_vs_put_many_race():
    """Pre-fix reproducer: keys() iterating the live dict while a flusher
    lands put_many raised RuntimeError (dict changed size mid-iteration)."""
    be = MemoryBackend()
    stop = threading.Event()
    errors = []

    def writer():
        i = 0
        while not stop.is_set():
            be.put_many([((0, 0, i + j), b"x") for j in range(16)])
            i += 16

    def scanner():
        try:
            while not stop.is_set():
                be.keys()
                be.get_many([(0, 0, 0), (0, 0, 1)])
                (0, 0, 2) in be
        except RuntimeError as e:  # pragma: no cover - the pre-fix failure
            errors.append(e)

    threads = [threading.Thread(target=writer) for _ in range(2)]
    threads += [threading.Thread(target=scanner) for _ in range(2)]
    for t in threads:
        t.start()
    time.sleep(0.4)
    stop.set()
    for t in threads:
        t.join()
    assert not errors


# ------------------------------------------------ tiered store oracle --


def tiered(root=None, **store_kw):
    return tiered_store(spec(), root=root, policy=log_policy(), **store_kw)


def oracle_and_subject(root=None, **store_kw):
    return CuboidStore(spec()), tiered(root, **store_kw)


def random_walk(ref, sub, seed, n_ops=120, compact_every=25):
    rng = np.random.default_rng(seed)
    for i in range(n_ops):
        m = int(rng.integers(0, N_CELLS))
        roll = rng.random()
        if roll < 0.45:
            data = rng.integers(0, 5, size=CUBOID).astype(np.uint8)
            if rng.random() < 0.3:
                data[:] = 0  # lazy-zero delete → log tombstone
            ref.write_cuboid(0, m, data)
            sub.write_cuboid(0, m, data)
        elif roll < 0.85:
            np.testing.assert_array_equal(
                sub.read_cuboid(0, m), ref.read_cuboid(0, m))
        else:
            lo, hi = rand_box(rng)
            np.testing.assert_array_equal(
                cutout(sub, 0, lo, hi), cutout(ref, 0, lo, hi))
        if i % compact_every == compact_every - 1:
            sub.compact()
    sub.flush()
    assert sub.stored_keys() == ref.stored_keys()
    np.testing.assert_array_equal(
        cutout(sub, 0, (0, 0, 0), SHAPE), cutout(ref, 0, (0, 0, 0), SHAPE))


@pytest.mark.parametrize("seed", [0, 1])
def test_tiered_store_matches_oracle(seed):
    ref, sub = oracle_and_subject()
    try:
        write_cutout(ref, 0, (0, 0, 0), volume(seed))
        write_cutout(sub, 0, (0, 0, 0), volume(seed))
        random_walk(ref, sub, seed)
    finally:
        sub.close()


def test_tiered_store_with_write_behind_matches_oracle():
    ref, sub = oracle_and_subject()
    enable_write_behind(sub, max_items=64, batch_items=16)
    try:
        random_walk(ref, sub, seed=7)
    finally:
        sub.close()


def test_acked_writes_survive_reopen(tmp_path):
    """Everything written before flush() returns must be readable from a
    brand-new store over the same root — the durability contract."""
    root = str(tmp_path)
    ref = CuboidStore(spec())
    sub = tiered(root)
    enable_write_behind(sub, max_items=64)
    write_cutout(ref, 0, (0, 0, 0), volume(3))
    write_cutout(sub, 0, (0, 0, 0), volume(3))
    rng = np.random.default_rng(3)
    for _ in range(30):
        m = int(rng.integers(0, N_CELLS))
        data = rng.integers(0, 5, size=CUBOID).astype(np.uint8)
        if rng.random() < 0.3:
            data[:] = 0
        ref.write_cuboid(0, m, data)
        sub.write_cuboid(0, m, data)
    sub.compact(max_segments=1)  # partially compacted: both tiers populated
    sub.flush()
    sub.close()
    reborn = tiered(root)
    assert reborn.stored_keys() == ref.stored_keys()
    np.testing.assert_array_equal(
        cutout(reborn, 0, (0, 0, 0), SHAPE), cutout(ref, 0, (0, 0, 0), SHAPE))
    reborn.close()


def test_migrate_on_log_tier_applies_tombstones():
    """migrate() on a log write tier must go through compaction: the old
    per-key loop skipped tombstones, leaving stale read-tier data."""
    sub = tiered()
    data = np.ones(CUBOID, dtype=np.uint8)
    sub.write_cuboid(0, 1, data)
    sub.compact()  # value now lives on the read tier
    sub.write_cuboid(0, 1, np.zeros(CUBOID, dtype=np.uint8))  # tombstone
    sub.migrate()
    assert not sub.has_cuboid(0, 1)
    assert (0, 0, 1) not in sub.read_backend  # really deleted, not shadowed
    assert sub.write_backend.stats()["tombstones"] == 0  # applied, dropped
    sub.close()


def test_background_compactor_converges():
    sub = tiered()
    comp = Compactor(sub, interval=0.01, min_sealed=1)
    ref = CuboidStore(spec())
    with comp:
        rng = np.random.default_rng(11)
        for _ in range(60):
            m = int(rng.integers(0, N_CELLS))
            data = rng.integers(0, 5, size=CUBOID).astype(np.uint8)
            ref.write_cuboid(0, m, data)
            sub.write_cuboid(0, m, data)
            sub.write_backend.seal_active()
            comp.poke()
        np.testing.assert_array_equal(
            cutout(sub, 0, (0, 0, 0), SHAPE), cutout(ref, 0, (0, 0, 0), SHAPE))
    sub.compact()
    s = sub.write_backend.stats()
    assert s["live_keys"] == 0 and s["sealed"] == 0  # fully drained
    assert sub.stored_keys() == ref.stored_keys()
    assert sub.compactions["runs"] >= 1
    sub.close()


# ----------------------------------------------------- crash recovery --


def test_crash_mid_flush_parks_queue_and_recovers(tmp_path, crash_at):
    root = str(tmp_path)
    sub = tiered(root)
    queue = enable_write_behind(sub, max_items=64, batch_items=8)
    data = np.full(CUBOID, 7, dtype=np.uint8)
    sub.write_cuboid(0, 1, data)
    sub.flush()  # acked: durable before the crash
    crash_at("wal.append.written")
    sub.write_cuboid(0, 2, data)
    with pytest.raises(RuntimeError):  # the park is loud, never silent
        sub.flush()
    set_crash_hook(None)
    assert queue.depth >= 1  # pending writes preserved, not dropped
    reborn = tiered(root)
    # the acked write survived; nothing is torn
    np.testing.assert_array_equal(reborn.read_cuboid(0, 1), data)
    got = reborn.read_cuboid(0, 2)
    assert (got == data).all() or not got.any()  # whole or absent
    reborn.close()


def test_crash_mid_compaction_recovers_bit_identical(tmp_path, crash_at):
    root = str(tmp_path)
    ref = CuboidStore(spec())
    sub = tiered(root)
    write_cutout(ref, 0, (0, 0, 0), volume(5))
    write_cutout(sub, 0, (0, 0, 0), volume(5))
    ref.write_cuboid(0, 2, np.zeros(CUBOID, dtype=np.uint8))
    sub.write_cuboid(0, 2, np.zeros(CUBOID, dtype=np.uint8))
    crash_at("compact.copied", after=1)  # die on the second batch
    with pytest.raises(SimulatedCrash):
        compact_store(sub, batch_keys=16)
    set_crash_hook(None)
    # live store already coherent: copied-but-not-dropped entries shadow
    # the read tier with identical bytes
    np.testing.assert_array_equal(
        cutout(sub, 0, (0, 0, 0), SHAPE), cutout(ref, 0, (0, 0, 0), SHAPE))
    sub.close()
    reborn = tiered(root)  # "restart": replay the surviving log suffix
    np.testing.assert_array_equal(
        cutout(reborn, 0, (0, 0, 0), SHAPE), cutout(ref, 0, (0, 0, 0), SHAPE))
    reborn.compact()  # re-running converges; no torn or resurrected keys
    assert reborn.stored_keys() == ref.stored_keys()
    assert reborn.write_backend.stats()["live_keys"] == 0
    reborn.close()


def test_crash_between_drop_and_remove_is_idempotent(tmp_path, crash_at):
    root = str(tmp_path)
    sub = tiered(root)
    data = np.full(CUBOID, 3, dtype=np.uint8)
    for m in range(8):
        sub.write_cuboid(0, m, data)
    crash_at("compact.segment-removed")
    with pytest.raises(SimulatedCrash):
        sub.compact()
    set_crash_hook(None)
    sub.close()
    reborn = tiered(root)
    for m in range(8):
        np.testing.assert_array_equal(reborn.read_cuboid(0, m), data)
    reborn.compact()
    assert reborn.write_backend.stats()["live_keys"] == 0
    reborn.close()


# ------------------------------------------------------- cluster scope --


def log_node_factory(i, dataset_spec):
    return tiered_store(dataset_spec, policy=log_policy())


@pytest.mark.parametrize("n_nodes", [1, 2, 4])
@pytest.mark.parametrize("tier", ["log", "memory"])
def test_sharded_tiered_matches_reference(n_nodes, tier):
    """Oracle identity across 1/2/4 shards x tiered/untiered, including
    migrate (per-node compaction), flush, and rebalance ops."""
    rng = np.random.default_rng(n_nodes * 5 + (tier == "log"))
    ops = [("write_cutout", [0, 0, 0], volume(seed=n_nodes))]
    ops += random_ops(rng, 40)
    kw = {"node_factory": log_node_factory} if tier == "log" else {}
    run_interleaving(n_nodes, ops, **kw)


@pytest.mark.parametrize("n_nodes", [2, 4])
def test_reads_bit_identical_during_live_compaction(n_nodes):
    """A background compactor hammering every shard mid-traffic must be
    invisible: reads stay bit-identical to the oracle throughout."""
    ref = CuboidStore(spec())
    sub = ClusterStore(spec(), n_nodes=n_nodes, node_factory=log_node_factory)
    stop = threading.Event()
    errors = []

    def compact_loop():
        try:
            while not stop.is_set():
                for node in sub.nodes:
                    node.write_backend.seal_active()
                sub.compact()
        except Exception as e:  # pragma: no cover
            errors.append(e)

    t = threading.Thread(target=compact_loop)
    t.start()
    try:
        write_cutout(ref, 0, (0, 0, 0), volume(9))
        write_cutout(sub, 0, (0, 0, 0), volume(9))
        rng = np.random.default_rng(9)
        for _ in range(80):
            m = int(rng.integers(0, N_CELLS))
            if rng.random() < 0.5:
                data = rng.integers(0, 5, size=CUBOID).astype(np.uint8)
                if rng.random() < 0.25:
                    data[:] = 0
                ref.write_cuboid(0, m, data)
                sub.write_cuboid(0, m, data)
            else:
                lo, hi = rand_box(rng)
                np.testing.assert_array_equal(
                    cutout(sub, 0, lo, hi), cutout(ref, 0, lo, hi))
        np.testing.assert_array_equal(
            cutout(sub, 0, (0, 0, 0), SHAPE), cutout(ref, 0, (0, 0, 0), SHAPE))
    finally:
        stop.set()
        t.join()
        sub.close()
    assert not errors


def test_cluster_default_factory_honors_write_tier_env(monkeypatch):
    monkeypatch.setenv("REPRO_WRITE_TIER", "log")
    monkeypatch.setenv("REPRO_FSYNC", "0")
    sub = ClusterStore(spec(), n_nodes=2)
    try:
        roots = [n._tier_tmpdir.name for n in sub.nodes]
        assert all(type(n.write_backend).__name__ == "LogBackend"
                   for n in sub.nodes)
        data = np.full(CUBOID, 9, dtype=np.uint8)
        sub.write_cuboid(0, 1, data)
        np.testing.assert_array_equal(sub.read_cuboid(0, 1), data)
    finally:
        sub.close()
    assert not any(os.path.exists(r) for r in roots)  # scratch reclaimed


# -------------------------------------- re-replication: failover + heal --


def test_failover_then_heal_coherence_walk():
    """The under-replication hole: shrink below replication target, then
    add a rider node — before re_replicate() nothing ever repairs the
    ring.  After healing, the cluster must survive losing either node."""
    ref = CuboidStore(spec())
    sub = ClusterStore(spec(), n_nodes=3, replication=2)
    vol = volume(13)
    write_cutout(ref, 0, (0, 0, 0), vol)
    write_cutout(sub, 0, (0, 0, 0), vol)
    sub.remove_node(0)
    sub.remove_node(0)  # 1 node: effective replication collapsed to 1
    sub.add_node(rebalance=False)  # rider outside the router
    topo = sub.topology()
    assert topo["replication"] == 1 and topo["replication_target"] == 2
    healed = sub.re_replicate()
    assert healed["healed"] and healed["moved_keys"] > 0
    topo = sub.topology()
    assert topo["replication"] == 2
    np.testing.assert_array_equal(
        cutout(sub, 0, (0, 0, 0), SHAPE), cutout(ref, 0, (0, 0, 0), SHAPE))
    # the heal is real: EITHER node can now fail with zero data loss
    sub.remove_node(0)
    np.testing.assert_array_equal(
        cutout(sub, 0, (0, 0, 0), SHAPE), cutout(ref, 0, (0, 0, 0), SHAPE))
    # idempotent on a healthy cluster
    again = sub.re_replicate()
    assert not again["healed"] and again["moved_keys"] == 0
    sub.close()


def test_supervisor_advises_and_executes_heal_and_compaction():
    sub = ClusterStore(spec(), n_nodes=2, replication=2,
                       node_factory=log_node_factory)
    vol = volume(17)
    write_cutout(sub, 0, (0, 0, 0), vol)
    for node in sub.nodes:
        node.write_backend.seal_active()
    watch = ClusterWatch(sub, max_sealed_segments=1)
    advice = {a["action"] for a in watch.step()}
    assert "compact" in advice
    sup = StorageSupervisor(sub, watch=watch)
    executed = {a["action"] for a in sup.step()}
    assert "compact" in executed
    assert sub.tier_counters()["sealed"] == 0
    # now open a replication gap; the supervisor heals it on its tick
    sub.remove_node(0)
    sub.add_node(rebalance=False)
    assert sub.topology()["replication"] < sub.topology()["replication_target"]
    executed = {a["action"] for a in sup.step()}
    assert "re_replicate" in executed
    assert sub.topology()["replication"] == 2
    np.testing.assert_array_equal(
        cutout(sub, 0, (0, 0, 0), SHAPE), vol)
    sub.close()


# ------------------------------------------------------- HTTP surface --


def test_compact_verb_and_tier_gauges():
    sub = ClusterStore(spec(), n_nodes=2, node_factory=log_node_factory)
    service = VolumeService()
    service.add_dataset("ds", sub)
    write_cutout(sub, 0, (0, 0, 0), volume(21))
    stats = url_dispatch(service, "GET", "/ds/stats")
    assert stats["tiers"]["log_nodes"] == 2
    assert stats["tiers"]["log_bytes"] > 0
    resp = url_dispatch(service, "POST", "/ds/compact")
    assert resp["status"] == 200 and resp["total_keys"] > 0
    after = url_dispatch(service, "GET", "/ds/stats")["tiers"]
    assert after["sealed"] == 0
    assert after["compactions"]["keys"] == resp["total_keys"]
    # bare /compact sweeps every dataset; wrong-method and unknowns 40x
    assert url_dispatch(service, "POST", "/compact")["status"] == 200
    assert url_dispatch(service, "GET", "/ds/compact")["status"] == 405
    assert url_dispatch(service, "POST", "/nope/compact")["status"] == 404
    assert url_dispatch(
        service, "POST", "/ds/compact", {"max_segments": "x"})["status"] == 400
    sub.close()
