"""ObjectIndex edge cases and cluster partitioning of object runs."""
import numpy as np

from repro.core.cuboid import CuboidGrid
from repro.core.spatial_index import ObjectIndex


def grid():
    return CuboidGrid(volume_shape=(64, 64, 32), cuboid_shape=(16, 16, 8))


def test_empty_object():
    idx = ObjectIndex()
    assert idx.cuboids(42) == []
    assert idx.runs(42) == []
    assert idx.bounding_box(42, grid()) is None
    assert idx.partitioned_runs(42, [(0, 32), (32, 64)]) == {}
    assert 42 not in idx


def test_single_cuboid_object():
    idx = ObjectIndex()
    idx.append_batch({7: [5]})
    assert idx.cuboids(7) == [5]
    assert idx.runs(7) == [(5, 6)]
    bbox = idx.bounding_box(7, grid())
    assert bbox is not None
    lo, hi = bbox
    g = grid()
    origin = g.cuboid_origin(5)
    assert lo == list(origin)
    assert hi == [o + c for o, c in zip(origin, g.cuboid_shape)]


def test_non_contiguous_morton_sets():
    idx = ObjectIndex()
    # two contiguous blocks with a hole, plus an isolated cell, appended
    # out of order and with duplicates across two batches
    idx.append_batch({1: [9, 3, 4, 5]})
    idx.append_batch({1: [4, 12, 13]})
    assert idx.cuboids(1) == [3, 4, 5, 9, 12, 13]       # sorted, deduped
    assert idx.runs(1) == [(3, 6), (9, 10), (12, 14)]   # collapsed runs
    assert idx.append_batches == 2


def test_bounding_box_clips_to_volume():
    g = CuboidGrid(volume_shape=(20, 20, 10), cuboid_shape=(16, 16, 8))
    idx = ObjectIndex()
    # last cell of the 2x2x2 grid: its cuboid extends past the volume
    last = g.cuboid_of_voxel((19, 19, 9))
    idx.append_batch({2: [last]})
    lo, hi = idx.bounding_box(2, g)
    assert hi == [20, 20, 10]  # clamped, not 32/32/16
    assert lo == [16, 16, 8]


def test_partitioned_runs_clip_at_segment_boundaries():
    idx = ObjectIndex()
    idx.append_batch({5: list(range(6, 22))})    # one run (6, 22)
    segments = [(0, 8), (8, 16), (16, 32)]
    parts = idx.partitioned_runs(5, segments)
    assert parts == {0: [(6, 8)], 1: [(8, 16)], 2: [(16, 22)]}
    # clipped pieces exactly re-cover the object
    covered = sorted(m for runs in parts.values()
                     for a, b in runs for m in range(a, b))
    assert covered == idx.cuboids(5)


def test_remove_and_ids():
    idx = ObjectIndex()
    idx.append_batch({1: [0], 3: [1], 2: [2]})
    assert idx.ids() == [1, 2, 3]
    idx.remove(3)
    assert idx.ids() == [1, 2]
    assert idx.runs(3) == []
    idx.remove(999)  # removing an absent id is a no-op
    assert idx.ids() == [1, 2]


def test_bounding_box_non_contiguous_spans_hole():
    g = grid()
    idx = ObjectIndex()
    m_a = g.cuboid_of_voxel((0, 0, 0))
    m_b = g.cuboid_of_voxel((48, 48, 24))
    idx.append_batch({4: [m_a, m_b]})
    lo, hi = idx.bounding_box(4, g)
    assert lo == [0, 0, 0]
    assert hi == [64, 64, 32]
    vox = np.prod([h - l for l, h in zip(lo, hi)])
    assert vox == 64 * 64 * 32
