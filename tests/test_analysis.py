"""Tests for the correctness tooling itself (`repro.analysis`).

The witness tests build private `Witness` instances so they can seed
violations without polluting the suite-wide witness the conftest guard
reads (a seeded ABBA here must not fail an unrelated test).
"""

import pathlib
import textwrap
import threading

import pytest

from repro.analysis import knobs, lints, witness
from repro.analysis.witness import OrderedLock, OrderedRLock

REPO = pathlib.Path(__file__).resolve().parents[1]


def _lint(src: str, path: str):
    return lints.run_source(textwrap.dedent(src), path)


def _rules(findings):
    return [f.rule for f in findings]


# --------------------------------------------------------------------------
# lock-order witness
# --------------------------------------------------------------------------


class TestWitness:
    def test_abba_cycle_detected_with_both_stacks(self):
        w = witness.Witness()
        a = OrderedLock("node.a", 40, witness=w)
        b = OrderedLock("node.b", 40, witness=w)

        def t1():
            with a:
                with b:
                    pass

        def t2():
            with b:
                with a:
                    pass

        for fn in (t1, t2):  # sequential, so the ABBA never actually hangs
            th = threading.Thread(target=fn)
            th.start()
            th.join()

        vs = w.take_violations()
        assert [v.kind for v in vs] == ["cycle"]
        assert "node.a" in vs[0].message and "node.b" in vs[0].message
        assert vs[0].stack and vs[0].other_stack  # both stacks reported

    def test_rank_inversion_detected(self):
        w = witness.Witness()
        outer = OrderedLock("cache", 60, witness=w)
        inner = OrderedLock("store", 40, witness=w)
        with outer:
            with inner:
                pass
        vs = w.take_violations()
        assert [v.kind for v in vs] == ["order"]
        assert "rank 40" in vs[0].message and "rank 60" in vs[0].message

    def test_ascending_ranks_are_clean(self):
        w = witness.Witness()
        a = OrderedLock("admin", 10, witness=w)
        b = OrderedLock("store", 40, witness=w)
        c = OrderedLock("wal", 50, witness=w)
        for _ in range(3):  # repeat: the known-edge fast path stays clean
            with a, b, c:
                pass
        assert w.take_violations() == []

    def test_rlock_reentry_is_not_an_edge(self):
        w = witness.Witness()
        admin = OrderedRLock("admin", 10, witness=w)
        store = OrderedLock("store", 40, witness=w)
        with admin:
            with admin:  # re-entry: no self-edge, no violation
                with store:
                    pass
        assert w.take_violations() == []
        assert w.held_snapshot() == {}

    def test_submit_while_ranked_lock_held(self):
        w = witness.Witness()
        lock = OrderedLock("store", 40, witness=w)
        with lock:
            w.before_submit()
        vs = w.take_violations()
        assert [v.kind for v in vs] == ["submit"]
        assert "'store'" in vs[0].message

    def test_submit_allowlist_suppresses(self):
        w = witness.Witness()
        move = OrderedLock("cluster.move", 20, witness=w)
        with move:
            w.before_submit(allow=(move,))
        assert w.take_violations() == []

    def test_failed_nonblocking_acquire_leaves_nothing_held(self):
        w = witness.Witness()
        lock = OrderedLock("store", 40, witness=w)
        assert lock.acquire(blocking=False)
        got = []
        th = threading.Thread(target=lambda: got.append(lock.acquire(blocking=False)))
        th.start()
        th.join()
        assert got == [False]
        lock.release()
        assert w.held_snapshot() == {}

    def test_factory_returns_plain_locks_when_disabled(self, monkeypatch):
        monkeypatch.setattr(witness, "ENABLED", False)
        assert type(witness.ordered_lock("x", 10)) is type(threading.Lock())
        assert type(witness.ordered_rlock("x", 10)) is type(threading.RLock())
        witness.before_submit()  # no-op, must not touch the global witness
        assert witness.GLOBAL.take_violations() == []

    def test_factory_returns_ordered_locks_when_enabled(self):
        # conftest turned the knob on for the suite
        assert isinstance(witness.ordered_lock("t.x", 10), OrderedLock)
        assert isinstance(witness.ordered_rlock("t.y", 10), OrderedRLock)


# --------------------------------------------------------------------------
# lint rules: one positive and one negative fixture each
# --------------------------------------------------------------------------


class TestL001Fsync:
    BAD = """
    import os

    def put(self, path, tmp, data):
        with open(tmp, "wb") as f:
            f.write(data)
            os.replace(tmp, path)  # published before durable!
            os.fsync(f.fileno())
    """
    GOOD = """
    import os

    def put(self, path, tmp, data):
        with open(tmp, "wb") as f:
            f.write(data)
            os.fsync(f.fileno())
        os.replace(tmp, path)
    """

    def test_positive(self):
        assert _rules(_lint(self.BAD, "core/store.py")) == ["L001"]

    def test_negative(self):
        assert _lint(self.GOOD, "core/store.py") == []

    def test_index_bind_before_fsync(self):
        src = """
        import os

        def append(self, key, loc, f):
            self._index[key] = loc
            os.fsync(f.fileno())
        """
        assert _rules(_lint(src, "core/wal.py")) == ["L001"]


class TestL002SubmitUnderLock:
    BAD = """
    def flush(self):
        with self._lock:
            return self.pool.submit(self._apply)
    """
    GOOD = """
    def flush(self):
        with self._lock:
            jobs = list(self._pending)
        return self.pool.submit(self._apply, jobs)
    """

    def test_positive(self):
        assert _rules(_lint(self.BAD, "x.py")) == ["L002"]

    def test_negative(self):
        assert _lint(self.GOOD, "x.py") == []


class TestL003KnobRegistry:
    BAD = """
    import os

    def level():
        return os.environ.get("REPRO_COMPRESS_LEVEL", "")
    """
    GOOD = """
    from repro.analysis import knobs

    def level():
        return knobs.get_int("REPRO_COMPRESS_LEVEL", 1)
    """

    def test_positive(self):
        findings = _lint(self.BAD, "x.py")
        assert _rules(findings) == ["L003"]
        assert "REPRO_COMPRESS_LEVEL" in findings[0].message

    def test_subscript_read(self):
        assert _rules(_lint("import os\nv = os.environ['REPRO_FSYNC']\n", "x.py")) == ["L003"]

    def test_negative(self):
        assert _lint(self.GOOD, "x.py") == []

    def test_knobs_module_itself_is_exempt(self):
        src = "import os\nv = os.environ.get('REPRO_FSYNC', '')\n"
        assert _lint(src, "src/repro/analysis/knobs.py") == []


class TestL004HandlerEnvelope:
    BAD = """
    def get_thing(service, request):
        return {"ok": True}

    HANDLERS = {"GET /thing": get_thing}
    """
    GOOD = """
    def _error(status, message):
        return {"status": status, "error": message}

    def get_thing(service, request):
        if "thing" not in request:
            return _error(400, "missing thing")
        body = {"status": 200, "thing": request["thing"]}
        return body

    HANDLERS = {"GET /thing": get_thing}
    """

    def test_positive(self):
        assert _rules(_lint(self.BAD, "handlers.py")) == ["L004"]

    def test_negative(self):
        assert _lint(self.GOOD, "handlers.py") == []


class TestL005SwallowedExceptions:
    BAD = """
    def migrate(self):
        try:
            self._copy()
        except Exception:
            return None
    """
    GOOD = """
    def migrate(self):
        try:
            self._copy()
        except Exception as e:
            self.last_error = repr(e)
            return None
    """

    def test_positive_in_storage_path(self):
        assert _rules(_lint(self.BAD, "cluster/store.py")) == ["L005"]

    def test_reraise_is_fine(self):
        src = """
        def migrate(self):
            try:
                self._copy()
            except Exception:
                self._rollback()
                raise
        """
        assert _lint(src, "cluster/store.py") == []

    def test_recording_is_fine(self):
        assert _lint(self.GOOD, "cluster/store.py") == []

    def test_out_of_scope_module_not_flagged(self):
        assert _lint(self.BAD, "serve/http_front.py") == []

    def test_bare_except_flagged_everywhere(self):
        src = "try:\n    x = 1\nexcept:\n    pass\n"
        assert _rules(_lint(src, "serve/http_front.py")) == ["L005"]

    def test_pragma_suppresses(self):
        src = """
        def migrate(self):
            try:
                self._copy()
            except Exception:  # lint: allow(L005) fallback is the contract
                return None
        """
        assert _lint(src, "cluster/store.py") == []


class TestDriver:
    def test_tree_is_clean(self):
        findings = lints.run_paths([str(REPO / "src")])
        assert findings == [], "\n".join(f.format() for f in findings)

    def test_check_cli_exits_zero(self, capsys):
        import importlib.util

        spec = importlib.util.spec_from_file_location(
            "check", REPO / "tools" / "check.py")
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)
        assert mod.main([str(REPO / "src")]) == 0
        assert "check clean" in capsys.readouterr().out


# --------------------------------------------------------------------------
# knob registry
# --------------------------------------------------------------------------


class TestKnobs:
    def test_every_knob_is_repro_prefixed_and_documented(self):
        for name, knob in knobs.REGISTRY.items():
            assert name.startswith("REPRO_")
            assert knob.doc and knob.default and knob.kind

    def test_table_round_trips(self):
        rows = knobs.parse_table(knobs.render_table())
        assert [r[0] for r in rows] == list(knobs.REGISTRY)
        for name, kind, default, doc in rows:
            knob = knobs.REGISTRY[name]
            assert (kind, default, doc) == (knob.kind, knob.default, knob.doc)

    def test_readme_table_is_fresh(self):
        text = (REPO / "README.md").read_text()
        assert not knobs.readme_stale(text), (
            "README knob table is stale; run `python tools/check.py --fix-readme`")

    def test_unregistered_knob_read_raises(self):
        with pytest.raises(KeyError):
            knobs.get_flag("REPRO_NOT_A_KNOB", False)

    def test_parsers(self, monkeypatch):
        monkeypatch.setenv("REPRO_FSYNC", "off")
        assert knobs.get_flag("REPRO_FSYNC", True) is False
        monkeypatch.setenv("REPRO_CACHE_BYTES", "123")
        assert knobs.get_int("REPRO_CACHE_BYTES", 0) == 123
        monkeypatch.delenv("REPRO_SLOW_MS", raising=False)
        assert knobs.get_float("REPRO_SLOW_MS", None) is None
        monkeypatch.setenv("REPRO_WRITE_TIER", "log")
        assert knobs.get_str("REPRO_WRITE_TIER", "dir") == "log"
