"""Roofline extraction unit tests (HLO collective parsing, terms)."""
import pytest

from repro.launch.roofline import (Roofline, collective_bytes,
                                   model_flops_estimate)
from repro.models.config import SHAPES


HLO = """
ENTRY %main {
  %ag = f32[256,1024]{1,0} all-gather(%x), dimensions={1}
  %ar.1 = bf16[512]{0} all-reduce(%y), to_apply=%add
  %ags = (f32[8,128]{1,0}, f32[8,128]{1,0}) all-gather-start(%a, %b)
  %agd = (f32[8,128]{1,0}, f32[8,128]{1,0}) all-gather-done(%ags)
  %rs = f32[64]{0} reduce-scatter(%z), dimensions={0}
  %cp = u8[32,32]{1,0} collective-permute(%w)
  %notacoll = f32[2,2]{1,0} add(%p, %q)
}
"""


def test_collective_bytes_parsing():
    got = collective_bytes(HLO)
    assert got["all-gather"] == 256 * 1024 * 4 + 2 * 8 * 128 * 4  # no -done
    assert got["all-reduce"] == 512 * 2
    assert got["reduce-scatter"] == 64 * 4
    assert got["collective-permute"] == 32 * 32
    assert got["all-to-all"] == 0


def test_roofline_terms_and_bottleneck():
    r = Roofline(flops=197e12, hbm_bytes=819e9 / 2,
                 coll_bytes={"all-reduce": int(50e9 / 4)}, n_chips=256,
                 model_flops=197e12 * 256 * 0.5)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(0.5)
    assert r.t_collective == pytest.approx(0.25)
    assert r.bottleneck == "compute"
    assert r.useful_flops_ratio == pytest.approx(0.5)
    assert r.roofline_fraction == pytest.approx(0.5)


def test_model_flops_moe_counts_active_only():
    from repro.configs import get_config
    arctic = get_config("arctic_480b")
    dense_equiv = get_config("llama3_405b")
    f_train = model_flops_estimate(arctic, SHAPES["train_4k"])
    # arctic total ~480B but active ~11-20B: estimate must be well under
    # 6 * 480e9 * tokens
    tokens = 256 * 4096
    assert f_train < 6 * 300e9 * tokens
    assert f_train > 6 * 5e9 * tokens
    # decode counts one token per sequence
    f_dec = model_flops_estimate(arctic, SHAPES["decode_32k"])
    assert f_dec == pytest.approx(
        f_train / (6 / 2) / (tokens / SHAPES["decode_32k"].global_batch))


def test_cell_applicability():
    from repro.configs import get_config
    from repro.launch.specs import cell_is_applicable
    full_attn = get_config("llama3_405b")
    ssm = get_config("mamba2_370m")
    hybrid = get_config("recurrentgemma_2b")
    ok, why = cell_is_applicable(full_attn, SHAPES["long_500k"])
    assert not ok and "full-attention" in why
    assert cell_is_applicable(ssm, SHAPES["long_500k"])[0]
    assert cell_is_applicable(hybrid, SHAPES["long_500k"])[0]
    assert cell_is_applicable(full_attn, SHAPES["decode_32k"])[0]