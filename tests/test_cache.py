"""Coherence + stress suite for the hot-cuboid cache tier and the
write-behind ingest queue (paper §6 vision).

The contract under test: a `ClusterStore` with a (deliberately tiny,
eviction-heavy) cache and a write-behind queue attached is **bit-identical
to an uncached single `CuboidStore`** under any interleaving of reads,
writes, cutouts, migrations, cache drops, and flushes — and the stats
counters stay consistent (every read is a cache hit or a cache miss).

Also here: the regression tests for the `migrate()` write-drop race and
for `DirectoryBackend.keys()` over trees containing foreign entries.
"""
import threading
import time

import numpy as np
import pytest

from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.cluster import (
    ClusterStore,
    CuboidCache,
    VolumeService,
    WriteBehindQueue,
    attach_cache,
    dispatch,
    enable_write_behind,
)
from repro.core.cuboid import DatasetSpec
from repro.core.cutout import cutout, ingest, write_cutout
from repro.core.store import CuboidStore, DirectoryBackend, MemoryBackend

SHAPE = (32, 32, 16)
CUBOID = (8, 8, 4)
N_CELLS = 64  # 4x4x4 grid


def spec(shape=SHAPE, **kw):
    return DatasetSpec(name="cc", volume_shape=shape, dtype="uint8",
                       base_cuboid=CUBOID, **kw)


def make_pair(n_nodes, cache_bytes=6 << 10, max_items=16):
    """(uncached reference store, cached+write-behind cluster under test).

    The default cache budget holds only a few segments, so eviction fires
    constantly — coherence must survive it.
    """
    ref = CuboidStore(spec())
    sub = ClusterStore(spec(), n_nodes=n_nodes, cache_bytes=cache_bytes,
                       write_behind=True, write_behind_items=max_items)
    return ref, sub


def rand_box(rng):
    lo = [int(rng.integers(0, s - 1)) for s in SHAPE]
    hi = [int(rng.integers(l + 1, s + 1)) for l, s in zip(lo, SHAPE)]
    return lo, hi


def apply_op(store, op):
    kind = op[0]
    if kind == "read_cuboid":
        return store.read_cuboid(0, op[1])
    if kind == "write_cuboid":
        store.write_cuboid(0, op[1], op[2])
        return None
    if kind == "cutout":
        return cutout(store, 0, op[1], op[2])
    if kind == "write_cutout":
        write_cutout(store, 0, op[1], op[2])
        return None
    if kind == "migrate":
        store.migrate()
        return None
    if kind == "flush":
        if hasattr(store, "flush"):
            store.flush()
        return None
    if kind == "drop_cache":
        # subject-only: dropping cached entries must be invisible
        if isinstance(store, ClusterStore):
            for node in store.nodes:
                if node.cache is not None:
                    node.cache.clear()
        return None
    raise AssertionError(f"unknown op {kind}")


def random_ops(rng, n_ops):
    grid_block = CUBOID
    ops = []
    for _ in range(n_ops):
        roll = rng.random()
        if roll < 0.20:
            ops.append(("read_cuboid", int(rng.integers(0, N_CELLS))))
        elif roll < 0.40:
            data = rng.integers(0, 4, size=grid_block).astype(np.uint8)
            if rng.random() < 0.2:
                data[:] = 0  # lazy-zero delete path
            ops.append(("write_cuboid", int(rng.integers(0, N_CELLS)), data))
        elif roll < 0.60:
            ops.append(("cutout", *rand_box(rng)))
        elif roll < 0.80:
            lo, hi = rand_box(rng)
            shape = [h - l for l, h in zip(lo, hi)]
            data = rng.integers(0, 255, size=shape).astype(np.uint8)
            ops.append(("write_cutout", lo, data))
        elif roll < 0.88:
            ops.append(("migrate",))
        elif roll < 0.94:
            ops.append(("flush",))
        else:
            ops.append(("drop_cache",))
    return ops


def run_interleaving(n_nodes, ops):
    ref, sub = make_pair(n_nodes)
    try:
        for op in ops:
            want = apply_op(ref, op)
            got = apply_op(sub, op)
            if want is not None:
                np.testing.assert_array_equal(got, want)
        # final state identical everywhere, through both read paths
        np.testing.assert_array_equal(
            cutout(sub, 0, (0, 0, 0), SHAPE), cutout(ref, 0, (0, 0, 0), SHAPE))
        sub.flush()
        assert sub.stored_keys() == ref.stored_keys()
        rs, ws = sub.read_stats, sub.write_stats
        assert rs.reads + ws.reads == rs.cache_hits + rs.cache_misses
    finally:
        sub.close()


# ------------------------------------------------------- coherence (seeded) --


@pytest.mark.parametrize("n_nodes", [1, 2, 4])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_cached_cluster_matches_uncached_reference(n_nodes, seed):
    """Random op interleavings: cached+write-behind cluster is bit-identical
    to the uncached reference store, under constant eviction."""
    rng = np.random.default_rng(seed * 7 + n_nodes)
    run_interleaving(n_nodes, random_ops(rng, 60))


if HAVE_HYPOTHESIS:

    @given(st.integers(min_value=0, max_value=2 ** 31 - 1),
           st.sampled_from([1, 2, 4]),
           st.integers(min_value=5, max_value=40))
    @settings(max_examples=25, deadline=None)
    def test_cached_cluster_coherence_property(seed, n_nodes, n_ops):
        rng = np.random.default_rng(seed)
        run_interleaving(n_nodes, random_ops(rng, n_ops))


def test_eviction_is_invisible_and_bounded():
    vol = np.random.default_rng(3).integers(1, 255, SHAPE, dtype=np.uint8)
    ref = CuboidStore(spec())
    ingest(ref, 0, vol)
    store = CuboidStore(spec())
    cache = attach_cache(store, CuboidCache(max_bytes=4 << 10, segment_bits=2))
    ingest(store, 0, vol)
    for seed in range(6):
        lo, hi = rand_box(np.random.default_rng(seed))
        np.testing.assert_array_equal(cutout(store, 0, lo, hi),
                                      cutout(ref, 0, lo, hi))
    assert cache.evictions > 0  # the budget really forced segment drops
    # budget holds whenever more than one segment is resident
    assert cache.n_segments <= 1 or cache.bytes <= cache.max_bytes


def test_cache_hit_miss_counters_warm_vs_cold():
    vol = np.random.default_rng(4).integers(1, 255, SHAPE, dtype=np.uint8)
    store = CuboidStore(spec())
    attach_cache(store, 64 << 20)
    ingest(store, 0, vol)
    box = ((0, 0, 0), SHAPE)
    cutout(store, 0, *box)
    h0, m0 = store.read_stats.cache_hits, store.read_stats.cache_misses
    cutout(store, 0, *box)  # warm: all hits, no new misses
    assert store.read_stats.cache_misses == m0
    assert store.read_stats.cache_hits == h0 + N_CELLS
    rs, ws = store.read_stats, store.write_stats
    assert rs.reads + ws.reads == rs.cache_hits + rs.cache_misses


def test_read_your_writes_before_flush():
    """A write is readable the moment the call returns, even while the
    write-behind queue still holds it (and durable only after flush)."""
    store = CuboidStore(spec(), backend=MemoryBackend(),
                        write_path_backend=MemoryBackend())
    attach_cache(store, 64 << 20)
    queue = enable_write_behind(store, max_items=256, batch_items=256)
    block = np.full(CUBOID, 7, np.uint8)
    for m in range(N_CELLS):
        store.write_cuboid(0, m, block)
        np.testing.assert_array_equal(store.read_cuboid(0, m), block)
    drained = store.flush()
    assert drained >= 0 and queue.depth == 0
    assert queue.applied == queue.enqueued == N_CELLS  # distinct keys
    # after the barrier every write is in the backend
    assert len(store.stored_keys()) == N_CELLS
    store.close()


# ----------------------------------------------------------------- stress --


def test_concurrent_cutouts_and_write_behind_ingest():
    """N threads hammer one cached+write-behind ClusterStore with
    overlapping cutouts and put_cutout-style writes: no deadlock, no lost
    writes after flush(), consistent counters."""
    n_threads, n_rounds = 6, 8
    base = np.random.default_rng(11).integers(1, 255, SHAPE, dtype=np.uint8)
    sub = ClusterStore(spec(), n_nodes=2, cache_bytes=32 << 10,
                       write_behind=True, write_behind_items=8)
    ingest(sub, 0, base)  # shared channel 0, read-only below
    refs = {t: CuboidStore(spec()) for t in range(n_threads)}
    failures = []

    def worker(tid):
        rng = np.random.default_rng(100 + tid)
        ch = tid + 1  # each thread owns one channel; channel 0 is shared
        try:
            for _ in range(n_rounds):
                lo, hi = rand_box(rng)
                shape = [h - l for l, h in zip(lo, hi)]
                data = rng.integers(1, 255, size=shape).astype(np.uint8)
                write_cutout(sub, 0, lo, data, channel=ch)
                write_cutout(refs[tid], 0, lo, data, channel=ch)
                lo2, hi2 = rand_box(rng)
                cutout(sub, 0, lo2, hi2)  # overlapping shared reads
        except Exception as e:  # pragma: no cover - surfaced via failures
            failures.append((tid, e))

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
        assert not t.is_alive(), "stress worker deadlocked"
    assert not failures, failures
    sub.flush()
    # no lost writes: every thread's channel equals its serial replay
    for tid in range(n_threads):
        np.testing.assert_array_equal(
            cutout(sub, 0, (0, 0, 0), SHAPE, channel=tid + 1),
            cutout(refs[tid], 0, (0, 0, 0), SHAPE, channel=tid + 1))
    # shared channel untouched by the ingest traffic
    np.testing.assert_array_equal(cutout(sub, 0, (0, 0, 0), SHAPE), base)
    rs, ws = sub.read_stats, sub.write_stats
    assert rs.reads + ws.reads == rs.cache_hits + rs.cache_misses
    q = sub.queue_counters()
    # applied <= enqueued: re-enqueues of a still-pending key coalesce
    # (last write wins) — but nothing may remain pending after flush
    assert q["depth"] == 0 and 0 < q["applied"] <= q["enqueued"]
    sub.close()


def test_write_behind_backpressure_bounds_queue():
    store = CuboidStore(spec())
    queue = enable_write_behind(store, max_items=4, batch_items=2)
    block = np.full(CUBOID, 9, np.uint8)
    for m in range(32):
        store.write_cuboid(0, m, block)
    store.flush()
    assert queue.depth_peak <= 4
    assert queue.applied == queue.enqueued == 32
    store.close()


def test_write_behind_retries_transient_flush_failures():
    """A flush failure no longer parks the queue: the batch is retried
    with backoff and applies once the backend recovers."""
    calls = {"n": 0}

    class FlakyBackend(MemoryBackend):
        def put_many(self, items):
            calls["n"] += 1
            if calls["n"] <= 2:
                raise IOError("transient disk error")
            super().put_many(items)

    store = CuboidStore(spec(), backend=FlakyBackend())
    queue = enable_write_behind(store, max_items=8)
    block = np.full(CUBOID, 1, np.uint8)
    store.write_cuboid(0, 0, block)
    store.write_cuboid(0, 1, block)
    store.flush()  # completes despite the two failed applies
    assert queue.counters()["flush_errors"] >= 1
    assert queue.counters()["poisoned"] == 0
    assert queue.depth == 0
    # the retry counters surface through PathStats
    assert store.write_stats.queue_retries == queue.retried
    store.close()
    np.testing.assert_array_equal(store.read_cuboid(0, 0), block)


def test_write_behind_poisons_persistently_failing_key(monkeypatch):
    """One persistently failing key is quarantined; the queue keeps
    serving every other key and flush() still completes."""
    monkeypatch.setenv("REPRO_WB_POISON_AFTER", "3")
    applied = {}

    def put_many(items):
        if any(k == ("bad",) for k, _ in items):
            raise IOError("cursed key")
        applied.update(dict(items))

    queue = WriteBehindQueue(put_many, lambda k: None,
                             max_items=8, batch_items=4)
    queue.enqueue(("bad",), b"x")
    queue.enqueue(("good",), b"y")
    queue.flush(timeout=30)  # the poisoned key counts as settled
    assert applied == {("good",): b"y"}
    assert ("bad",) in queue.poison_keys()
    assert queue.counters()["poisoned"] == 1
    # the queue keeps serving after the quarantine
    queue.enqueue(("more",), b"z")
    queue.flush(timeout=30)
    assert applied[("more",)] == b"z"
    # re-enqueueing a poisoned key gives it a fresh chance (and
    # re-poisons here, since this key never stops failing)
    queue.enqueue(("bad",), b"x2")
    assert ("bad",) not in queue.poison_keys()
    queue.flush(timeout=30)
    assert ("bad",) in queue.poison_keys()
    assert queue.counters()["poisoned"] == 2
    queue.close()


def test_write_behind_close_is_idempotent_and_store_survives():
    store = CuboidStore(spec())
    enable_write_behind(store)
    block = np.full(CUBOID, 3, np.uint8)
    store.write_cuboid(0, 1, block)
    store.close()
    store.close()  # second close is a no-op
    # after close the store falls back to synchronous writes
    store.write_cuboid(0, 2, block)
    np.testing.assert_array_equal(store.read_cuboid(0, 1), block)
    np.testing.assert_array_equal(store.read_cuboid(0, 2), block)


# ------------------------------------------------- migrate race regression --


class HookedWritePath(MemoryBackend):
    """Write-path backend whose ``get`` fires a one-shot hook — used to
    open the historical migrate() window deterministically."""

    def __init__(self):
        super().__init__()
        self.hook = None

    def get(self, key):
        hook, self.hook = self.hook, None
        if hook is not None:
            hook(key)
        return super().get(key)


def test_migrate_does_not_drop_concurrent_write():
    """Regression: a write landing between migrate()'s get and delete used
    to be silently dropped.  Per-key migration is now atomic under the
    store lock, so the racing write survives on the write path."""
    write_path = HookedWritePath()
    store = CuboidStore(spec(), backend=MemoryBackend(),
                        write_path_backend=write_path)
    old = np.full(CUBOID, 1, np.uint8)
    new = np.full(CUBOID, 2, np.uint8)
    store.write_cuboid(0, 0, old)

    racer = threading.Thread(target=lambda: store.write_cuboid(0, 0, new))

    def hook(key):
        # fired from inside migrate's critical section: the racing write
        # must serialize against it, not interleave
        racer.start()
        time.sleep(0.15)  # give the racer every chance to sneak in

    write_path.hook = hook
    store.migrate()
    racer.join(timeout=10)
    assert not racer.is_alive()
    np.testing.assert_array_equal(store.read_cuboid(0, 0), new)
    # the racing write survived on some path (not silently dropped)
    assert store.has_cuboid(0, 0)
    store.migrate()
    np.testing.assert_array_equal(store.read_cuboid(0, 0), new)


def test_migrate_flushes_write_behind_first():
    store = CuboidStore(spec(), backend=MemoryBackend(),
                        write_path_backend=MemoryBackend())
    enable_write_behind(store)
    block = np.full(CUBOID, 5, np.uint8)
    for m in range(8):
        store.write_cuboid(0, m, block)
    n = store.migrate()
    assert n == 8  # nothing in flight was missed
    assert len(list(store.write_backend.keys())) == 0
    assert len(list(store.read_backend.keys())) == 8
    store.close()


# -------------------------------------------- DirectoryBackend hardening --


def test_directory_backend_keys_skips_foreign_entries(tmp_path):
    root = str(tmp_path / "db")
    backend = DirectoryBackend(root)
    backend.put((0, 0, 5), b"blob5")
    backend.put((1, 2, 9), b"blob9")
    # foreign droppings at every level of the tree
    (tmp_path / "db" / "README.md").write_text("not a resolution dir")
    (tmp_path / "db" / "scratch").mkdir()
    (tmp_path / "db" / "0" / "notes.txt").write_text("not a channel dir")
    (tmp_path / "db" / "0" / "0" / "foreign.bin").write_text("not hex")
    (tmp_path / "db" / "0" / "0" / "data.json").write_text("{}")
    (tmp_path / "db" / "0" / "0" / f"{7:016x}.bin.tmp").write_text("torn")
    (tmp_path / "db" / "0" / "0" / f"{3:016x}.bin").mkdir()
    assert sorted(backend.keys()) == [(0, 0, 5), (1, 2, 9)]
    # a store over the dirty tree still enumerates and reads cleanly
    store = CuboidStore(spec(), backend=backend)
    assert store.stored_keys() == [(0, 0, 5), (1, 2, 9)]


# ------------------------------------------------------------ service verbs --


def test_flush_and_stats_verbs():
    svc = VolumeService()
    store = ClusterStore(spec(), n_nodes=2, cache_bytes=1 << 20,
                         write_behind=True)
    vol = np.random.default_rng(9).integers(1, 255, SHAPE, dtype=np.uint8)
    ingest(store, 0, vol)
    svc.add_dataset("d", store)

    put = dispatch(svc, {"verb": "PUT /cutout", "dataset": "d",
                         "lo": (4, 4, 4),
                         "data": np.full((8, 8, 4), 42, np.uint8)})
    assert put["status"] == 200

    got = dispatch(svc, {"verb": "GET /cutout", "dataset": "d",
                         "lo": (4, 4, 4), "hi": (12, 12, 8)})
    np.testing.assert_array_equal(got["data"], 42)  # read-your-writes

    fl = dispatch(svc, {"verb": "POST /flush", "dataset": "d"})
    assert fl["status"] == 200 and "d" in fl["flushed"]
    assert dispatch(svc, {"verb": "POST /flush"})["status"] == 200
    assert dispatch(svc, {"verb": "POST /flush",
                          "dataset": "nope"})["status"] == 404

    stats = dispatch(svc, {"verb": "GET /stats", "dataset": "d"})
    assert stats["status"] == 200
    assert stats["read"]["cache_hits"] + stats["read"]["cache_misses"] > 0
    # the coherence invariant must survive cluster-level aggregation
    assert stats["read"]["reads"] + stats["write"]["reads"] == (
        stats["read"]["cache_hits"] + stats["read"]["cache_misses"])
    assert stats["cache"]["hits"] >= 0 and stats["queue"]["depth"] == 0
    # gauges aggregate as max, not sum: summing per-node peaks over-reports
    # on multi-node clusters
    assert stats["write"]["queue_peak"] == max(
        n.write_stats.queue_peak for n in store.nodes)
    assert stats["write"]["queue_peak"] < sum(
        max(n.write_stats.queue_peak, 1) for n in store.nodes)
    assert dispatch(svc, {"verb": "GET /stats",
                          "dataset": "nope"})["status"] == 404

    sync = dispatch(svc, {"verb": "PUT /cutout", "dataset": "d",
                          "lo": (0, 0, 0), "sync": True,
                          "data": np.full((8, 8, 4), 17, np.uint8)})
    assert sync["status"] == 200 and "flushed" in sync
    store.close()


def test_write_behind_queue_peek_and_last_write_wins():
    applied = {}

    def put_many(items):
        applied.update(items)

    def delete(key):
        applied.pop(key, None)

    gate = threading.Lock()
    gate.acquire()  # hold the apply lock so writes stay pending

    queue = WriteBehindQueue(put_many, delete, apply_lock=gate,
                             max_items=8, batch_items=4)
    try:
        queue.enqueue((0, 0, 1), b"v1")
        queue.enqueue((0, 0, 1), b"v2")  # rewrite: replaces, never blocks
        queue.enqueue((0, 0, 2), None)
        assert queue.peek((0, 0, 1)) == (True, b"v2")
        assert queue.peek((0, 0, 2)) == (True, None)
        assert queue.peek((0, 0, 3)) == (False, None)
        assert queue.depth == 2
        gate.release()
        queue.flush()
        assert applied == {(0, 0, 1): b"v2"}
        assert queue.peek((0, 0, 1)) == (False, None)
    finally:
        queue.close()


def test_cache_env_knobs(monkeypatch):
    monkeypatch.setenv("REPRO_CACHE_BYTES", str(1 << 20))
    monkeypatch.setenv("REPRO_WRITE_BEHIND", "1")
    store = ClusterStore(spec(), n_nodes=2)
    assert store.has_cache
    assert all(n.write_behind is not None for n in store.nodes)
    store.close()
    monkeypatch.setenv("REPRO_CACHE_BYTES", "0")
    monkeypatch.setenv("REPRO_WRITE_BEHIND", "0")
    store = ClusterStore(spec(), n_nodes=2)
    assert not store.has_cache
    assert all(n.write_behind is None for n in store.nodes)
    store.close()
