"""Cutout engine vs numpy-slicing oracle (paper §4.2)."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.cuboid import DatasetSpec
from repro.core.cutout import (CutoutStats, batch_cutout, build_hierarchy,
                               cutout, ingest, project, write_cutout)
from repro.core.store import CuboidStore, MemoryBackend


def make_store(shape=(64, 64, 32), cuboid=(16, 16, 8), dtype="uint8",
               n_res=1, write_path=False):
    spec = DatasetSpec(name="t", volume_shape=shape, n_resolutions=n_res,
                       dtype=dtype, base_cuboid=cuboid)
    return CuboidStore(
        spec, write_path_backend=MemoryBackend() if write_path else None)


@pytest.fixture(scope="module")
def loaded():
    rng = np.random.default_rng(0)
    vol = rng.integers(1, 255, size=(64, 64, 32), dtype=np.uint8)
    store = make_store()
    ingest(store, 0, vol)
    return store, vol


def boxes(shape):
    return st.tuples(*[st.tuples(st.integers(0, s - 1), st.integers(1, s))
                       for s in shape]).map(
        lambda t: ([min(a, b - 1) for a, b in t], [max(a + 1, b) for a, b in t]))


@given(box=boxes((64, 64, 32)))
@settings(max_examples=60, deadline=None)
def test_cutout_matches_numpy(loaded, box):
    store, vol = loaded
    lo, hi = box
    got = cutout(store, 0, lo, hi)
    want = vol[tuple(slice(l, h) for l, h in zip(lo, hi))]
    np.testing.assert_array_equal(got, want)


def test_cutout_stats_alignment(loaded):
    store, vol = loaded
    s_al, s_un = CutoutStats(), CutoutStats()
    cutout(store, 0, (16, 16, 8), (48, 48, 24), stats=s_al)
    cutout(store, 0, (17, 17, 9), (49, 49, 25), stats=s_un)
    assert s_al.bytes_discarded == 0
    assert s_un.bytes_discarded > 0  # unaligned reads+discards (Fig 10)
    assert s_un.cuboids_read >= s_al.cuboids_read


def test_aligned_box_single_run(loaded):
    store, _ = loaded
    stats = CutoutStats()
    cutout(store, 0, (0, 0, 0), (32, 32, 16), stats=stats)
    assert stats.runs == 1  # pow2-aligned => contiguous on the curve


def test_write_disciplines():
    store = make_store(dtype="uint32")
    a = np.full((8, 8, 8), 7, dtype=np.uint32)
    write_cutout(store, 0, (0, 0, 0), a)
    b = np.full((8, 8, 8), 9, dtype=np.uint32)
    write_cutout(store, 0, (4, 4, 4), b, discipline="preserve")
    out = cutout(store, 0, (0, 0, 0), (12, 12, 12))
    assert (out[:8, :8, :8] == 7).all()           # preserved
    assert (out[8:, 8:, 8:] == 9).all()           # new region written
    write_cutout(store, 0, (4, 4, 4), b, discipline="overwrite")
    out = cutout(store, 0, (4, 4, 4), (12, 12, 12))
    assert (out == 9).all()


def test_write_zero_voxels_do_not_clobber():
    store = make_store(dtype="uint32")
    write_cutout(store, 0, (0, 0, 0), np.full((8, 8, 8), 5, np.uint32))
    patch = np.zeros((8, 8, 8), np.uint32)
    patch[0, 0, 0] = 6
    write_cutout(store, 0, (0, 0, 0), patch, discipline="overwrite")
    out = cutout(store, 0, (0, 0, 0), (8, 8, 8))
    assert out[0, 0, 0] == 6
    assert (out.ravel()[1:] == 5).all()  # zeros in data leave old labels


def test_lazy_allocation():
    store = make_store()
    # nothing written: reads are zeros, storage is empty
    out = cutout(store, 0, (0, 0, 0), (64, 64, 32))
    assert not out.any()
    assert store.storage_bytes() == 0
    write_cutout(store, 0, (0, 0, 0), np.ones((4, 4, 4), np.uint8))
    assert store.storage_bytes() > 0
    assert len(store.stored_keys()) == 1  # only the touched cuboid


def test_write_path_separation_and_migration():
    store = make_store(write_path=True)
    write_cutout(store, 0, (0, 0, 0), np.ones((16, 16, 8), np.uint8))
    # all writes landed on the write path (SSD node)
    assert store.write_stats.writes > 0
    assert len(list(store.read_backend.keys())) == 0
    assert len(list(store.write_backend.keys())) == 1
    # reads see the fresh data through the write path
    assert cutout(store, 0, (0, 0, 0), (2, 2, 2)).all()
    n = store.migrate()
    assert n == 1
    assert len(list(store.write_backend.keys())) == 0
    assert cutout(store, 0, (0, 0, 0), (2, 2, 2)).all()


def test_projection_slice_and_mip(loaded):
    store, vol = loaded
    tile = project(store, 0, (0, 0, 5), (64, 64, 6), axis=2)
    np.testing.assert_array_equal(tile, vol[:, :, 5])
    mip = project(store, 0, (0, 0, 0), (64, 64, 32), axis=2, reduce="max")
    np.testing.assert_array_equal(mip, vol.max(axis=2))


def test_batch_cutout(loaded):
    store, vol = loaded
    bxs = [((0, 0, 0), (8, 8, 8)), ((10, 11, 12), (20, 21, 22))]
    outs = batch_cutout(store, 0, bxs)
    for (lo, hi), out in zip(bxs, outs):
        np.testing.assert_array_equal(
            out, vol[tuple(slice(l, h) for l, h in zip(lo, hi))])


def test_anisotropic_hierarchy():
    spec = DatasetSpec(name="h", volume_shape=(64, 64, 16), n_resolutions=3,
                       dtype="float32", base_cuboid=(16, 16, 8))
    store = CuboidStore(spec)
    rng = np.random.default_rng(1)
    vol = rng.random((64, 64, 16), dtype=np.float32)
    ingest(store, 0, vol)
    build_hierarchy(store)
    # level 1: X,Y halve, Z unchanged (paper Fig 5)
    g1 = spec.grid(1)
    assert g1.volume_shape == (32, 32, 16)
    got = cutout(store, 1, (0, 0, 0), (32, 32, 16))
    want = vol.reshape(32, 2, 32, 2, 16).mean(axis=(1, 3)).astype(np.float32)
    np.testing.assert_allclose(got, want, rtol=1e-6)
    g2 = spec.grid(2)
    assert g2.volume_shape == (16, 16, 16)


def test_cuboid_shapes_flat_then_cubic():
    spec = DatasetSpec(name="b", volume_shape=(4096, 4096, 512),
                       n_resolutions=6)
    assert spec.grid(0).cuboid_shape == (128, 128, 16)   # flat at high res
    assert spec.grid(5).cuboid_shape == (64, 64, 64)     # cubic past level 4
    for r in range(6):
        cs = spec.grid(r).cuboid_shape
        assert np.prod(cs) <= (1 << 18)  # paper: 256K voxels per cuboid


def test_4d_timeseries_curve():
    spec = DatasetSpec(name="ts", volume_shape=(32, 32, 8, 16),
                       scaled_dims=(0, 1), base_cuboid=(8, 8, 4, 4))
    store = CuboidStore(spec, )
    rng = np.random.default_rng(2)
    vol = rng.integers(0, 255, size=(32, 32, 8, 16), dtype=np.uint8)
    ingest(store, 0, vol)
    got = cutout(store, 0, (3, 4, 1, 2), (19, 22, 7, 13))
    np.testing.assert_array_equal(got, vol[3:19, 4:22, 1:7, 2:13])


def test_multichannel_separate_cuboids():
    spec = DatasetSpec(name="ch", volume_shape=(16, 16, 8), n_channels=3,
                       base_cuboid=(8, 8, 4), dtype="uint16")
    store = CuboidStore(spec)
    for c in range(3):
        write_cutout(store, 0, (0, 0, 0),
                     np.full((16, 16, 8), c + 1, np.uint16), channel=c)
    for c in range(3):
        assert (cutout(store, 0, (0, 0, 0), (16, 16, 8), channel=c)
                == c + 1).all()
