"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, asserting output shapes + no NaNs; plus decode-consistency checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import build_model, count_params, init_params
from repro.models.params import ParamSpec


def make(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = init_params(model.specs(), jax.random.key(0))
    return cfg, model, params


def batch_for(cfg, B=2, S=32):
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, size=(B, S)))
    if cfg.family == "encdec":
        frames = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)).astype(np.float32),
            dtype=jnp.bfloat16)
        return {"tokens": tokens, "frames": frames}
    if cfg.frontend == "patch_stub":
        emb = jnp.asarray(
            rng.normal(size=(B, cfg.n_frontend_tokens,
                             cfg.d_model)).astype(np.float32),
            dtype=jnp.bfloat16)
        return {"tokens": tokens, "embeds": emb}
    return {"tokens": tokens}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nans(arch):
    cfg, model, params = make(arch)
    B, S = 2, 32
    batch = batch_for(cfg, B, S)
    if cfg.family == "encdec":
        logits, aux = jax.jit(model.forward)(params, batch["tokens"],
                                             batch["frames"])
        S_out = S
    elif "embeds" in batch:
        logits, aux = jax.jit(
            lambda p, t, e: model.forward(p, t, embeds=e))(
                params, batch["tokens"], batch["embeds"])
        S_out = S + cfg.n_frontend_tokens
    else:
        logits, aux = jax.jit(model.forward)(params, batch["tokens"])
        S_out = S
    assert logits.shape == (B, S_out, cfg.vocab)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expect = {
        "smollm_135m": (30, 576, 9, 3, 1536, 49152),
        "minitron_8b": (32, 4096, 32, 8, 16384, 256000),
        "llama3_405b": (126, 16384, 128, 8, 53248, 128256),
        "gemma_2b": (18, 2048, 8, 1, 16384, 256000),
        "arctic_480b": (35, 7168, 56, 8, 4864, 32000),
        "granite_moe_1b_a400m": (24, 1024, 16, 8, 512, 49155),
        "internvl2_76b": (80, 8192, 64, 8, 28672, 128256),
        "recurrentgemma_2b": (26, 2560, 10, 1, 7680, 256000),
        "seamless_m4t_medium": (12, 1024, 16, 16, 4096, 256206),
        "mamba2_370m": (48, 1024, 0, 0, 0, 50280),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == expect
    if arch == "arctic_480b":
        assert (cfg.n_experts, cfg.top_k, cfg.moe_dense_residual) == (
            128, 2, True)
    if arch == "granite_moe_1b_a400m":
        assert (cfg.n_experts, cfg.top_k) == (32, 8)
    if arch == "mamba2_370m":
        assert cfg.ssm_state == 128
    if arch == "recurrentgemma_2b":
        assert cfg.hybrid_pattern == "RRA" and cfg.local_window == 2048


@pytest.mark.parametrize("arch", ["smollm_135m", "gemma_2b", "arctic_480b",
                                  "recurrentgemma_2b", "mamba2_370m",
                                  "seamless_m4t_medium"])
def test_decode_step_runs(arch):
    cfg, model, params = make(arch)
    B, cache_len = 2, 16
    if cfg.family == "encdec":
        cache = jax.tree.map(
            lambda s: jnp.zeros(s.shape, jnp.dtype(s.dtype)),
            model.cache_specs(B, cache_len, enc_len=8),
            is_leaf=lambda x: isinstance(x, ParamSpec))
    else:
        cache = jax.tree.map(
            lambda s: jnp.zeros(s.shape, jnp.dtype(s.dtype)),
            model.cache_specs(B, cache_len),
            is_leaf=lambda x: isinstance(x, ParamSpec))
    token = jnp.zeros((B, 1), jnp.int32)
    step = jax.jit(model.decode_step)
    logits, cache = step(params, cache, token, jnp.int32(0))
    logits, cache = step(params, cache, token + 1, jnp.int32(1))
    assert logits.shape == (B, 1, cfg.vocab)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())


@pytest.mark.parametrize("arch", ["smollm_135m", "mamba2_370m",
                                  "recurrentgemma_2b"])
def test_decode_matches_forward(arch):
    """Greedy decode logits == teacher-forced forward logits (consistency
    between the quadratic train path and the recurrent/cached decode path —
    for ssm this checks the state-space *duality* directly)."""
    cfg, model, params = make(arch)
    B, S = 1, 12
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, size=(B, S)))
    full_logits, _ = model.forward(params, tokens)
    cache = jax.tree.map(
        lambda s: jnp.zeros(s.shape, jnp.dtype(s.dtype)),
        model.cache_specs(B, S),
        is_leaf=lambda x: isinstance(x, ParamSpec))
    step = jax.jit(model.decode_step)
    outs = []
    for i in range(S):
        lg, cache = step(params, cache, tokens[:, i:i + 1], jnp.int32(i))
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32),
        np.asarray(full_logits, np.float32), atol=0.55, rtol=0.1)
    # argmax agreement is the serving-relevant property
    agree = (np.asarray(dec_logits.argmax(-1))
             == np.asarray(full_logits.argmax(-1))).mean()
    assert agree >= 0.9


def test_param_counts_match_scale():
    """Full-config param counts are in the advertised ballpark."""
    for arch, lo, hi in [("smollm_135m", 0.10e9, 0.18e9),
                         ("gemma_2b", 1.5e9, 3.5e9),
                         ("minitron_8b", 6e9, 10e9),
                         ("mamba2_370m", 0.25e9, 0.5e9),
                         ("llama3_405b", 380e9, 430e9),
                         ("arctic_480b", 420e9, 530e9)]:
        cfg = get_config(arch)
        n = count_params(build_model(cfg).specs())
        assert lo <= n <= hi, f"{arch}: {n/1e9:.1f}B not in [{lo/1e9}, {hi/1e9}]"


def test_moe_aux_loss_positive():
    cfg, model, params = make("granite_moe_1b_a400m")
    batch = batch_for(cfg)
    _, aux = jax.jit(model.forward)(params, batch["tokens"])
    assert float(aux) >= 0.0


def test_remat_dots_policy_equivalence():
    """forward under remat='dots' == remat='block' == 'none' (values)."""
    from repro.configs import get_config
    from repro.models import build_model
    import jax, numpy as np
    cfg0 = get_config("smollm_135m").scaled(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=256, dtype="float32")
    tok = jnp.asarray(np.random.default_rng(0).integers(
        0, 256, size=(2, 32)), jnp.int32)
    outs = {}
    for remat in ("none", "block", "dots"):
        model = build_model(cfg0.scaled(remat=remat))
        params = init_params(model.specs(), jax.random.key(7))
        outs[remat], _ = model.forward(params, tok)
    np.testing.assert_allclose(np.asarray(outs["none"]),
                               np.asarray(outs["block"]), atol=1e-5)
    np.testing.assert_allclose(np.asarray(outs["none"]),
                               np.asarray(outs["dots"]), atol=1e-5)


def test_fused_prefill_kv_equivalence():
    """fused_prefill_kv=True produces the same logits and cache."""
    from repro.configs import get_config
    from repro.models import build_model
    import jax, numpy as np
    cfg0 = get_config("minitron_8b").scaled(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=256, dtype="float32")
    tok = jnp.asarray(np.random.default_rng(1).integers(
        0, 256, size=(2, 24)), jnp.int32)
    model = build_model(cfg0)
    params = init_params(model.specs(), jax.random.key(3))
    lg0, c0 = model.prefill(params, tok, cache_len=32)
    model_f = build_model(cfg0.scaled(fused_prefill_kv=True))
    lg1, c1 = model_f.prefill(params, tok, cache_len=32)
    np.testing.assert_allclose(np.asarray(lg0), np.asarray(lg1), atol=1e-5)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), atol=1e-5), c0, c1)


def test_fused_prefill_kv_moe_equivalence():
    from repro.configs import get_config
    from repro.models import build_model
    import jax, numpy as np
    cfg0 = get_config("granite_moe_1b_a400m").scaled(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=64,
        n_experts=4, top_k=2, vocab=256, dtype="float32")
    tok = jnp.asarray(np.random.default_rng(2).integers(
        0, 256, size=(2, 16)), jnp.int32)
    model = build_model(cfg0)
    params = init_params(model.specs(), jax.random.key(5))
    lg0, c0 = model.prefill(params, tok, cache_len=24)
    model_f = build_model(cfg0.scaled(fused_prefill_kv=True))
    lg1, c1 = model_f.prefill(params, tok, cache_len=24)
    np.testing.assert_allclose(np.asarray(lg0), np.asarray(lg1), atol=1e-5)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), atol=1e-5), c0, c1)


def test_decode_step_flash_flag_equivalence():
    """decode_step(use_flash_decode=True) == jnp path, end to end."""
    import jax, numpy as np
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config("minitron_8b").scaled(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=256, dtype="float32")
    model = build_model(cfg)
    params = init_params(model.specs(), jax.random.key(11))
    tok = jnp.asarray(np.random.default_rng(4).integers(
        0, 256, size=(2, 12)), jnp.int32)
    _, cache = model.prefill(params, tok, cache_len=16)
    nxt = jnp.asarray([[7], [9]], jnp.int32)
    lg0, _ = model.decode_step(params, cache, nxt, jnp.int32(12))
    model_f = build_model(cfg.scaled(use_flash_decode=True))
    lg1, _ = model_f.decode_step(params, cache, nxt, jnp.int32(12))
    np.testing.assert_allclose(np.asarray(lg0), np.asarray(lg1),
                               atol=1e-4, rtol=1e-4)
