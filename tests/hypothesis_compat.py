"""Graceful degradation when `hypothesis` is not installed.

Tier-1 must *collect* everywhere (the seed failed at collection on the
missing import).  ``from hypothesis import ...`` is replaced in test modules
by ``from hypothesis_compat import given, settings, st, HAVE_HYPOTHESIS``:
with hypothesis present these are the real objects; without it, ``st`` is an
inert strategy stand-in (absorbs any attribute/call at decoration time) and
``@given`` swaps the property test for a skipped stub — so oracle tests in
the same module still run.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # degrade: property tests skip, the rest still run
    HAVE_HYPOTHESIS = False

    class _InertStrategy:
        """Stand-in for `st.*`: evaluated only at decoration time."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    st = _InertStrategy()

    def given(*args, **kwargs):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def skipped():
                pass

            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped

        return deco

    def settings(*args, **kwargs):
        def deco(fn):
            return fn

        return deco


__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
