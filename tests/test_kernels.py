"""Per-kernel allclose vs pure-jnp oracles, swept over shapes/dtypes.

All kernels execute in interpret mode on CPU; on TPU the same code paths
compile via Mosaic (interpret=None auto-detects backend).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.cuboid import CuboidGrid
from repro.core.distributed import pack_to_cuboids
from repro.kernels.cutout_gather.ops import cutout_gather
from repro.kernels.cutout_gather.ref import cutout_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.morton_matmul.ops import morton_matmul, panel_traffic
from repro.kernels.morton_matmul.ref import matmul_ref
from repro.models.layers import blockwise_attention

RNG = np.random.default_rng(42)


def rand(shape, dtype):
    x = RNG.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype=dtype)


def tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(
        atol=2e-5, rtol=2e-5)


# ------------------------------------------------- flash attention sweep ----

ATTN_SHAPES = [
    # (B, Sq, Skv, H, K, D)
    (1, 64, 64, 4, 4, 64),     # MHA square
    (2, 128, 128, 8, 2, 64),   # GQA
    (1, 96, 96, 4, 1, 128),    # MQA, non-pow2 seq (padding path)
    (1, 32, 128, 4, 2, 64),    # cross/prefix: fewer q than kv
    (2, 64, 64, 4, 4, 256),    # big head dim (gemma-style)
]


@pytest.mark.parametrize("shape", ATTN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 48)])
def test_flash_attention_vs_ref(shape, dtype, causal, window):
    B, Sq, Skv, H, K, D = shape
    q = rand((B, Sq, H, D), dtype)
    k = rand((B, Skv, K, D), dtype)
    v = rand((B, Skv, K, D), dtype)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=32, block_kv=32)
    want = attention_ref(q, k, v, causal=causal, scale=D ** -0.5,
                         window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


def test_flash_attention_matches_blockwise_jnp():
    """Kernel == the jnp blockwise path used for roofline dry-runs."""
    B, S, H, K, D = 2, 128, 8, 4, 64
    q, k, v = rand((B, S, H, D), jnp.float32), rand(
        (B, S, K, D), jnp.float32), rand((B, S, K, D), jnp.float32)
    a = flash_attention(q, k, v, causal=True, block_q=32, block_kv=64)
    b = blockwise_attention(q, k, v, causal=True, scale=D ** -0.5,
                            block_q=32, block_kv=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5,
                               rtol=2e-5)


# --------------------------------------------------- morton matmul sweep ----

MM_SHAPES = [(256, 128, 256), (512, 256, 512), (128, 128, 128),
             (384, 256, 128),  # non-pow2 tile grid (clamped curve cells)
             (256, 96, 200)]   # padding path


@pytest.mark.parametrize("mnk", MM_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("order", ["morton", "hilbert", "rowmajor"])
def test_morton_matmul_vs_ref(mnk, dtype, order):
    M, N, K = mnk
    a = rand((M, K), dtype)
    b = rand((K, N), dtype)
    got = morton_matmul(a, b, block_m=128, block_n=128, block_k=64,
                        order=order)
    want = matmul_ref(a, b)
    got = np.asarray(got, np.float32)
    want = np.asarray(want, np.float32)
    rel = np.abs(got - want) / (np.abs(want) + 1.0)
    assert rel.max() < (3e-2 if dtype == jnp.bfloat16 else 1e-4)


def test_curve_traversal_panel_traffic():
    """The locality claims (paper §3 Hilbert-vs-Morton trade-off, adapted
    to VMEM panel reuse):
      - capacity=1 (Pallas consecutive-DMA-skip): Hilbert optimal — every
        step changes exactly ONE coordinate; Morton actually loses.
      - capacity>=2 (explicit panel cache / GPU L2 swizzle): Morton beats
        row-major by ~2x on square grids.
    """
    for nm, nn in [(8, 8), (16, 16), (32, 32)]:
        ht1 = panel_traffic(nm, nn, "hilbert", capacity=1)
        rt1 = panel_traffic(nm, nn, "rowmajor", capacity=1)
        zt1 = panel_traffic(nm, nn, "morton", capacity=1)
        assert ht1 == nm * nn + 1          # provably optimal
        assert ht1 < rt1 < zt1, (nm, nn, ht1, rt1, zt1)
        zt4 = panel_traffic(nm, nn, "morton", capacity=4)
        rt4 = panel_traffic(nm, nn, "rowmajor", capacity=4)
        assert zt4 < rt4, (nm, nn, zt4, rt4)
    assert (panel_traffic(32, 32, "rowmajor", 4)
            / panel_traffic(32, 32, "morton", 4)) > 1.4


def test_hilbert_decode_properties():
    from repro.core.morton import hilbert_decode_2d
    import numpy as np
    for order in (1, 2, 3, 4):
        n = 1 << (2 * order)
        xs, ys = hilbert_decode_2d(np.arange(n), order)
        # bijective onto the grid
        assert len({(int(x), int(y)) for x, y in zip(xs, ys)}) == n
        # unit-step: consecutive cells are grid neighbors (the property
        # Morton lacks and the paper cites as Hilbert's advantage)
        d = np.abs(np.diff(xs)) + np.abs(np.diff(ys))
        assert (d == 1).all()


# --------------------------------------------------- cutout gather sweep ----


@pytest.mark.parametrize("dtype", ["float32", "uint8"])
@pytest.mark.parametrize("box", [((0, 0, 0), (32, 32, 16)),
                                 ((8, 16, 8), (40, 48, 16)),
                                 ((5, 3, 2), (37, 45, 14))])  # unaligned
def test_cutout_gather_vs_ref(dtype, box):
    grid = CuboidGrid((64, 64, 32), (8, 8, 8))
    vol = RNG.integers(0, 200, size=grid.volume_shape).astype(dtype)
    packed = jnp.asarray(pack_to_cuboids(vol, grid))
    lo, hi = box
    got = cutout_gather(packed, grid, lo, hi)
    want = cutout_ref(packed, grid, lo, hi)
    np.testing.assert_array_equal(np.asarray(got), want)


@given(data=st.data())
@settings(max_examples=20, deadline=None)
def test_cutout_gather_property(data):
    grid = CuboidGrid((32, 32, 16), (8, 8, 4))
    vol = RNG.integers(0, 255, size=grid.volume_shape).astype(np.int32)
    packed = jnp.asarray(pack_to_cuboids(vol, grid))
    lo = [data.draw(st.integers(0, s - 1)) for s in grid.volume_shape]
    hi = [data.draw(st.integers(l + 1, s))
          for l, s in zip(lo, grid.volume_shape)]
    got = cutout_gather(packed, grid, lo, hi)
    want = vol[tuple(slice(l, h) for l, h in zip(lo, hi))]
    np.testing.assert_array_equal(np.asarray(got), want)


# ------------------------------------------------------- ssd scan sweep ----

from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref

SSD_SHAPES = [
    # (B, S, H, P, N, chunk)
    (1, 64, 2, 32, 32, 32),     # two chunks
    (2, 128, 4, 64, 64, 32),    # four chunks, wider
    (1, 96, 2, 32, 64, 32),     # S multiple of chunk, N > P
    (1, 80, 3, 16, 32, 32),     # padding path (80 % 32 != 0)
    (2, 64, 2, 64, 128, 64),    # single chunk == mamba2-370m N
]


@pytest.mark.parametrize("shape", SSD_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_vs_ref(shape, dtype):
    B, S, H, P, N, chunk = shape
    x = rand((B, S, H, P), dtype)
    dt = jax.nn.softplus(rand((B, S, H), jnp.float32))
    A = -jnp.exp(rand((H,), jnp.float32) * 0.5)
    Bm = rand((B, S, N), dtype)
    Cm = rand((B, S, N), dtype)
    y, s = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    y_ref, s_ref = ssd_ref(x, dt, A, Bm, Cm)
    # chunked vs fully-quadratic associate differently: allow fp32 drift
    t = tol(dtype) if dtype == jnp.bfloat16 else dict(atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), **t)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), **t)


def test_ssd_scan_matches_model_chunked():
    """Kernel == the jnp chunked path used by models/ssm.py."""
    from repro.models.ssm import _ssd_chunked
    B, S, H, P, N, chunk = 2, 128, 4, 32, 64, 32
    x = rand((B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(rand((B, S, H), jnp.float32))
    A = -jnp.exp(rand((H,), jnp.float32) * 0.5)
    Bm = rand((B, S, N), jnp.float32)
    Cm = rand((B, S, N), jnp.float32)
    y_k, s_k = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    y_m, s_m = _ssd_chunked(x, dt, A, Bm, Cm, chunk)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_m),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_m),
                               atol=2e-5, rtol=2e-5)


def test_ssm_block_kernel_flag_equivalence():
    """ssm_block(use_ssd_kernel=True) == ssm_block(False) end to end."""
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config("mamba2_370m").scaled(
        n_layers=2, d_model=64, ssm_state=32, ssm_head_dim=16,
        vocab=128, ssm_chunk=16, dtype="float32")
    from repro.models.params import init_params
    model = build_model(cfg)
    params = init_params(model.specs(), jax.random.key(0))
    tokens = jnp.asarray(RNG.integers(0, 128, size=(2, 48)), jnp.int32)
    logits_jnp, _ = model.forward(params, tokens)
    cfg_k = cfg.scaled(use_ssd_kernel=True)
    model_k = build_model(cfg_k)
    logits_k, _ = model_k.forward(params, tokens)
    np.testing.assert_allclose(np.asarray(logits_jnp),
                               np.asarray(logits_k), atol=1e-4, rtol=1e-4)


@given(data=st.data())
@settings(max_examples=10, deadline=None)
def test_ssd_scan_property(data):
    """Property: kernel matches quadratic oracle on random small shapes."""
    B = data.draw(st.integers(1, 2))
    H = data.draw(st.integers(1, 3))
    P = data.draw(st.sampled_from([8, 16, 32]))
    N = data.draw(st.sampled_from([16, 32]))
    chunk = data.draw(st.sampled_from([8, 16]))
    S = data.draw(st.integers(8, 72))
    x = rand((B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(rand((B, S, H), jnp.float32))
    A = -jnp.exp(rand((H,), jnp.float32) * 0.5)
    Bm = rand((B, S, N), jnp.float32)
    Cm = rand((B, S, N), jnp.float32)
    y, s = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    y_ref, s_ref = ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=3e-5, rtol=3e-5)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               atol=3e-5, rtol=3e-5)


# ---------------------------------------------------- flash decode sweep ----

from repro.kernels.flash_decode.ops import flash_decode
from repro.models.layers import decode_attention

FD_SHAPES = [
    # (B, S, H, K, D, cache_len, block_kv)
    (2, 128, 8, 2, 64, 128, 32),    # full cache
    (1, 256, 4, 4, 64, 100, 64),    # partial cache (masking)
    (2, 96, 4, 1, 128, 50, 32),     # MQA, non-pow2 S (padding path)
    (1, 64, 8, 8, 64, 1, 64),       # single valid position
]


@pytest.mark.parametrize("shape", FD_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_vs_ref(shape, dtype):
    B, S, H, K, D, clen, bkv = shape
    q = rand((B, 1, H, D), dtype)
    kc = rand((B, S, K, D), dtype)
    vc = rand((B, S, K, D), dtype)
    got = flash_decode(q, kc, vc, clen, scale=D ** -0.5, block_kv=bkv)
    want = decode_attention(q, kc, vc, clen, scale=D ** -0.5)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


def test_flash_decode_per_batch_lens():
    """Per-sequence cache lengths (continuous batching) mask correctly."""
    B, S, H, K, D = 3, 64, 4, 2, 64
    q = rand((B, 1, H, D), jnp.float32)
    kc = rand((B, S, K, D), jnp.float32)
    vc = rand((B, S, K, D), jnp.float32)
    lens = jnp.asarray([5, 33, 64], jnp.int32)
    got = flash_decode(q, kc, vc, lens, scale=D ** -0.5, block_kv=16)
    want = decode_attention(q, kc, vc, lens, scale=D ** -0.5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


# ------------------------------------------------------- moe gemm sweep ----

from repro.kernels.moe_gemm.ops import moe_gemm
from repro.kernels.moe_gemm.ref import moe_gemm_ref

MG_SHAPES = [
    # (E, C, d, f, block_c)
    (4, 64, 32, 16, 32),      # even tiles
    (8, 96, 64, 32, 32),      # imbalanced counts
    (2, 50, 32, 64, 16),      # padding path (50 % 16 != 0)
    (32, 40, 64, 32, 8),      # granite-like: many tiny experts
]


@pytest.mark.parametrize("shape", MG_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_gemm_vs_ref(shape, dtype):
    E, C, d, f, bc = shape
    x = rand((E, C, d), dtype)
    wg = rand((E, d, f), dtype)
    wu = rand((E, d, f), dtype)
    wd = rand((E, f, d), dtype)
    counts = jnp.asarray(RNG.integers(0, C + 1, size=(E,)), jnp.int32)
    # zero out buffer rows past counts (as the dispatch would leave them)
    mask = jnp.arange(C)[None, :] < counts[:, None]
    x = x * mask[..., None].astype(x.dtype)
    got = moe_gemm(x, wg, wu, wd, counts, block_c=bc)
    want = moe_gemm_ref(x, wg, wu, wd, counts)
    # intermediates are O(d*sqrt(f)) with cancellation in y: scale-aware tol
    t = (dict(atol=1e-3, rtol=1e-3) if dtype == jnp.float32
         else dict(atol=5e-2, rtol=5e-2))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **t)


def test_moe_gemm_skips_match_dense_einsum():
    """Kernel == the einsum path inside models.moe (zero-padded rows)."""
    E, C, d, f = 4, 32, 32, 16
    x = rand((E, C, d), jnp.float32)
    counts = jnp.asarray([32, 10, 0, 25], jnp.int32)
    mask = jnp.arange(C)[None, :] < counts[:, None]
    x = x * mask[..., None]
    wg, wu, wd = rand((E, d, f), jnp.float32), rand(
        (E, d, f), jnp.float32), rand((E, f, d), jnp.float32)
    got = moe_gemm(x, wg, wu, wd, counts, block_c=8)
    g = jnp.einsum("ecd,edf->ecf", x, wg)
    u = jnp.einsum("ecd,edf->ecf", x, wu)
    h = jax.nn.silu(g) * u
    want = jnp.einsum("ecf,efd->ecd", h, wd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
