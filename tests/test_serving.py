"""Continuous batching: per-sequence decode positions + slot scheduler.

The correctness bar: every request generated through the shared-slot
engine must produce EXACTLY the tokens it would produce decoded alone
(greedy decoding is deterministic; slots must not leak state).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.models.params import init_params
from repro.serve import ContinuousBatcher, Request

RNG = np.random.default_rng(7)


def small_cfg(arch="minitron_8b", **kw):
    base = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                vocab=97, dtype="float32")
    base.update(kw)
    return get_config(arch).scaled(**base)


def reference_decode(model, params, prompt, max_new, cache_len):
    """Single-request greedy decode through decode_step (B=1)."""
    from repro.models.params import ParamSpec
    cache = jax.tree.map(
        lambda s: jnp.zeros(s.shape, jnp.dtype(s.dtype)),
        model.cache_specs(1, cache_len),
        is_leaf=lambda x: isinstance(x, ParamSpec))
    out = []
    tok = jnp.asarray([[prompt[0]]], jnp.int32)
    for pos in range(len(prompt) + max_new - 1):
        logits, cache = model.decode_step(params, cache, tok,
                                          jnp.int32(pos))
        nxt = int(jnp.argmax(logits[0, -1]))
        if pos + 1 < len(prompt):
            tok = jnp.asarray([[prompt[pos + 1]]], jnp.int32)
        else:
            out.append(nxt)
            tok = jnp.asarray([[nxt]], jnp.int32)
    return out


@pytest.mark.parametrize("arch", ["minitron_8b", "granite_moe_1b_a400m"])
def test_continuous_batching_matches_solo_decode(arch):
    kw = {}
    if arch == "granite_moe_1b_a400m":
        kw = dict(n_experts=4, top_k=2, d_ff=64)
    cfg = small_cfg(arch, **kw)
    model = build_model(cfg)
    params = init_params(model.specs(), jax.random.key(0))
    prompts = [RNG.integers(0, cfg.vocab, size=n).tolist()
               for n in (3, 5, 8, 4)]
    max_new = 6
    eng = ContinuousBatcher(model, cfg, params, n_slots=2, cache_len=32)
    for rid, p in enumerate(prompts):
        eng.submit(Request(rid, p, max_new))
    got = eng.run()
    assert set(got) == set(range(len(prompts)))
    assert eng.occupancy > 0.5          # slots stay busy under backlog
    for rid, p in enumerate(prompts):
        want = reference_decode(model, params, p, max_new, cache_len=32)
        assert got[rid] == want, (rid, got[rid], want)


def test_continuous_batching_eos_frees_slot():
    cfg = small_cfg()
    model = build_model(cfg)
    params = init_params(model.specs(), jax.random.key(1))
    # find the first greedy token of a probe prompt, use it as EOS so the
    # request terminates immediately after one generated token
    probe = [5, 11, 23]
    first = reference_decode(model, params, probe, 1, cache_len=32)[0]
    eng = ContinuousBatcher(model, cfg, params, n_slots=1, cache_len=32)
    eng.submit(Request(0, probe, max_new=8, eos_id=first))
    eng.submit(Request(1, [4, 2], max_new=2))
    got = eng.run()
    assert got[0] == [first]            # stopped at EOS, not max_new
    assert len(got[1]) == 2             # queued request got the slot


def test_per_seq_index_matches_scalar_index():
    """decode_step with (B,) index == scalar index when all positions
    agree (the continuous-batching plumbing is a strict generalization)."""
    cfg = small_cfg()
    model = build_model(cfg)
    params = init_params(model.specs(), jax.random.key(2))
    tok = jnp.asarray(RNG.integers(0, cfg.vocab, size=(3, 12)), jnp.int32)
    _, cache = model.prefill(params, tok, cache_len=16)
    nxt = jnp.asarray([[1], [2], [3]], jnp.int32)
    lg_scalar, _ = model.decode_step(params, cache, nxt, jnp.int32(12))
    lg_vec, _ = model.decode_step(params, cache, nxt,
                                  jnp.asarray([12, 12, 12], jnp.int32))
    np.testing.assert_allclose(np.asarray(lg_scalar), np.asarray(lg_vec),
                               atol=1e-5, rtol=1e-5)


def test_hybrid_per_seq_index():
    """Hybrid (rotating-window cache) also supports vector positions."""
    cfg = get_config("recurrentgemma_2b").scaled(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=1, d_ff=128,
        vocab=97, d_rnn=64, local_window=8, dtype="float32")
    model = build_model(cfg)
    params = init_params(model.specs(), jax.random.key(3))
    from repro.models.params import ParamSpec
    cache = jax.tree.map(
        lambda s: jnp.zeros(s.shape, jnp.dtype(s.dtype)),
        model.cache_specs(2, 16),
        is_leaf=lambda x: isinstance(x, ParamSpec))
    tok = jnp.asarray([[5], [9]], jnp.int32)
    lg_s, _ = model.decode_step(params, cache, tok, jnp.int32(0))
    lg_v, _ = model.decode_step(params, cache, tok,
                                jnp.asarray([0, 0], jnp.int32))
    np.testing.assert_allclose(np.asarray(lg_s), np.asarray(lg_v),
                               atol=1e-5, rtol=1e-5)


def test_encdec_per_seq_index():
    """Encoder-decoder decode also supports vector positions."""
    cfg = get_config("seamless_m4t_medium").scaled(
        n_layers=2, n_enc_layers=2, n_dec_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab=97, dtype="float32")
    model = build_model(cfg)
    params = init_params(model.specs(), jax.random.key(5))
    from repro.models.params import ParamSpec
    cache = jax.tree.map(
        lambda s: jnp.zeros(s.shape, jnp.dtype(s.dtype)),
        model.cache_specs(2, 12, enc_len=8),
        is_leaf=lambda x: isinstance(x, ParamSpec))
    # fill cross K/V from a stub encoder memory
    mem = jnp.asarray(RNG.normal(size=(2, 8, 64)), jnp.float32)
    xk, xv = model.build_cross_cache(params, mem)
    cache = jax.tree.map(lambda c: c, cache)
    cache["decoder"]["xk"] = jnp.moveaxis(xk, 0, 0)
    cache["decoder"]["xv"] = jnp.moveaxis(xv, 0, 0)
    tok = jnp.asarray([[5], [9]], jnp.int32)
    lg_s, _ = model.decode_step(params, cache, tok, jnp.int32(3))
    lg_v, _ = model.decode_step(params, cache, tok,
                                jnp.asarray([3, 3], jnp.int32))
    np.testing.assert_allclose(np.asarray(lg_s), np.asarray(lg_v),
                               atol=1e-5, rtol=1e-5)


def test_ssm_continuous_batching():
    """Attention-free family through the slot engine (state caches)."""
    cfg = get_config("mamba2_370m").scaled(
        n_layers=2, d_model=64, ssm_state=16, ssm_head_dim=16,
        vocab=97, ssm_chunk=8, dtype="float32")
    model = build_model(cfg)
    params = init_params(model.specs(), jax.random.key(6))
    prompts = [RNG.integers(0, cfg.vocab, size=n).tolist() for n in (3, 6)]
    eng = ContinuousBatcher(model, cfg, params, n_slots=1, cache_len=24)
    for rid, p in enumerate(prompts):
        eng.submit(Request(rid, p, 4))
    got = eng.run()
    for rid, p in enumerate(prompts):
        want = reference_decode(model, params, p, 4, cache_len=24)
        assert got[rid] == want, (rid, got[rid], want)
