"""Annotation projects: metadata, disciplines, exceptions, index, analysis."""
import numpy as np
import pytest

from repro.core.annotations import Annotation, AnnotationProject
from repro.core.cuboid import DatasetSpec
from repro.core.store import MemoryBackend


def image_spec(shape=(64, 64, 32), n_res=1):
    return DatasetSpec(name="img", volume_shape=shape, n_resolutions=n_res,
                       dtype="uint8", base_cuboid=(16, 16, 8))


@pytest.fixture
def proj():
    return AnnotationProject("anno", image_spec(), enable_exceptions=True)


def blob(val, shape=(6, 6, 6)):
    return np.full(shape, val, dtype=np.uint32)


def test_metadata_crud_and_predicates(proj):
    s1 = proj.meta.create(ann_type="synapse", confidence=0.995, weight=1.5)
    s2 = proj.meta.create(ann_type="synapse", confidence=0.4)
    seg = proj.meta.create(ann_type="segment", neuron=12)
    assert proj.meta.query(("ann_type", "eq", "synapse")) == [s1.ann_id,
                                                             s2.ann_id]
    # paper example: objects/type/synapse/confidence/geq/0.99
    assert proj.meta.query(("ann_type", "eq", "synapse"),
                           ("confidence", "geq", 0.99)) == [s1.ann_id]
    assert proj.meta.query(("neuron", "eq", 12)) == [seg.ann_id]
    proj.meta.update(s2.ann_id, confidence=0.999, custom_field="x")
    assert proj.meta.get(s2.ann_id).kv["custom_field"] == "x"
    proj.meta.delete(seg.ann_id)
    assert proj.meta.get(seg.ann_id) is None


def test_write_read_and_region_query(proj):
    a = proj.meta.create(ann_type="synapse")
    proj.write(0, (2, 3, 4), blob(a.ann_id))
    out = proj.read(0, (2, 3, 4), (8, 9, 10))
    assert (out == a.ann_id).all()
    assert proj.objects_in_region(0, (0, 0, 0), (16, 16, 16)) == [a.ann_id]
    assert proj.objects_in_region(0, (32, 32, 16), (64, 64, 32)) == []


def test_object_cutout_filters_other_ids(proj):
    a = proj.meta.create()
    b = proj.meta.create()
    proj.write(0, (0, 0, 0), blob(a.ann_id, (8, 8, 8)))
    proj.write(0, (8, 0, 0), blob(b.ann_id, (8, 8, 8)))
    lo, dense = proj.object_cutout(a.ann_id, 0)
    assert set(np.unique(dense)) <= {0, a.ann_id}
    assert (dense == a.ann_id).sum() == 8 * 8 * 8


def test_voxel_list_sparse_object(proj):
    a = proj.meta.create()
    vol = np.zeros((16, 16, 8), np.uint32)
    pts = [(0, 0, 0), (15, 15, 7), (3, 9, 2)]
    for p in pts:
        vol[p] = a.ann_id
    proj.write(0, (8, 8, 8), vol)
    vl = proj.voxel_list(a.ann_id, 0)
    got = {tuple(r) for r in vl.tolist()}
    assert got == {(8 + x, 8 + y, 8 + z) for x, y, z in pts}


def test_index_runs_and_bbox(proj):
    a = proj.meta.create()
    proj.write(0, (0, 0, 0), blob(a.ann_id, (32, 8, 8)))
    cubes = proj.index.cuboids(a.ann_id)
    assert cubes == sorted(cubes) and len(cubes) == 2
    bbox = proj.bounding_box(a.ann_id, 0)
    lo, hi = bbox
    assert lo == [0, 0, 0]
    assert hi[0] >= 32 and hi[1] >= 8 and hi[2] >= 8


def test_exceptions_discipline(proj):
    a, b = proj.meta.create(), proj.meta.create()
    proj.write(0, (0, 0, 0), blob(a.ann_id, (4, 4, 4)))
    proj.write(0, (0, 0, 0), blob(b.ann_id, (4, 4, 4)),
               discipline="exception")
    # primary label preserved; second label recorded as exception
    labels = proj.voxel_labels(0, (1, 1, 1))
    assert set(labels) == {a.ann_id, b.ann_id}
    # a voxel not multiply labeled has one label
    proj.write(0, (8, 8, 8), blob(b.ann_id, (2, 2, 2)))
    assert proj.voxel_labels(0, (8, 8, 8)) == [b.ann_id]


def test_exception_requires_enable():
    p = AnnotationProject("noexc", image_spec(), enable_exceptions=False)
    a = p.meta.create()
    with pytest.raises(ValueError):
        p.write(0, (0, 0, 0), blob(a.ann_id), discipline="exception")


def test_readonly_project():
    p = AnnotationProject("ro", image_spec(), readonly=True)
    with pytest.raises(PermissionError):
        p.write(0, (0, 0, 0), blob(1))


def test_deferred_propagation():
    p = AnnotationProject("hier", image_spec(n_res=2))
    a = p.meta.create()
    p.write(0, (0, 0, 0), blob(a.ann_id, (8, 8, 8)))
    # visible at write resolution, stale elsewhere (paper §3.2)
    assert p.pending_propagation
    assert not p.read(1, (0, 0, 0), (4, 4, 8)).any()
    p.propagate()
    assert not p.pending_propagation
    out = p.read(1, (0, 0, 0), (4, 4, 8))
    assert (out == a.ann_id).all()


def test_batch_write_objects(proj):
    objs = [(Annotation(0, ann_type="synapse", confidence=0.9 + i / 100),
             (i * 8, 0, 0), np.ones((4, 4, 4), np.uint32))
            for i in range(3)]
    ids = proj.batch_write_objects(0, objs)
    assert len(set(ids)) == 3
    got = proj.batch_read_objects(ids, 0)
    for i in ids:
        lo, dense = got[i]
        assert (dense == i).sum() == 64


def test_distance_and_centroid(proj):
    a, b = proj.meta.create(), proj.meta.create()
    va = np.zeros((4, 4, 4), np.uint32)
    va[0, 0, 0] = a.ann_id
    vb = np.zeros((4, 4, 4), np.uint32)
    vb[0, 0, 0] = b.ann_id
    proj.write(0, (0, 0, 0), va)
    proj.write(0, (10, 0, 0), vb)
    assert proj.distance(a.ann_id, b.ann_id, 0) == pytest.approx(10.0)
    np.testing.assert_allclose(proj.centroid(a.ann_id, 0), [0, 0, 0])


def test_write_path_backend_for_annotations():
    p = AnnotationProject("ssd", image_spec(),
                          write_path_backend=MemoryBackend())
    a = p.meta.create()
    p.write(0, (0, 0, 0), blob(a.ann_id))
    assert len(list(p.store.write_backend.keys())) > 0
    assert len(list(p.store.read_backend.keys())) == 0
    p.store.migrate()
    assert len(list(p.store.write_backend.keys())) == 0
    assert (p.read(0, (0, 0, 0), (2, 2, 2)) == a.ann_id).all()
